//! Pluggable SVM backends for SVEN.
//!
//! [`RustBackend`] solves in-process with the Newton solvers of
//! [`crate::solvers::svm`] — the "SVEN (CPU)" line of the paper's figures.
//! The XLA backend (see [`crate::runtime`]) implements the same trait over
//! AOT-compiled artifacts — "SVEN (XLA)", the stand-in for "SVEN (GPU)".
//!
//! Backends prepare from a [`Design`], so a sparse data set flows through
//! preparation (gram blocks via the CSR/CSC join, Xᵀy via sparse GEMV)
//! and every per-point solve without densifying.

use crate::linalg::{vecops, Design, Mat};
use crate::solvers::svm::{
    dual_newton, primal_newton, samples::reduction_gram, samples::reduction_labels,
    DualOptions, PrimalOptions, ReducedSamples, SampleSet,
};

/// Primal/dual selection. `Auto` applies the paper's rule: primal when
/// 2p > n (weight dimension n is the small side), dual otherwise.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmMode {
    Auto,
    Primal,
    Dual,
}

impl SvmMode {
    /// Resolve `Auto` for a given problem shape.
    pub fn resolve(self, n: usize, p: usize) -> SvmMode {
        match self {
            SvmMode::Auto => {
                if 2 * p > n {
                    SvmMode::Primal
                } else {
                    SvmMode::Dual
                }
            }
            m => m,
        }
    }
}

/// Warm-start state carried between path points.
#[derive(Clone, Debug, Default)]
pub struct SvmWarm {
    /// Primal weights (length n).
    pub w: Option<Vec<f64>>,
    /// Dual variables (length 2p).
    pub alpha: Option<Vec<f64>>,
}

/// Output of one SVM solve in reduction space.
#[derive(Clone, Debug)]
pub struct SvmSolve {
    /// Dual variables, length 2p.
    pub alpha: Vec<f64>,
    /// Primal weights if the backend produced them (length n).
    pub w: Option<Vec<f64>>,
    /// Newton iterations / pivots.
    pub iters: usize,
}

/// A data set prepared for repeated (t, C) solves.
///
/// Deliberately not `Send`: the XLA backend holds PJRT handles (Rc-based
/// in the xla crate), so preparations are thread-local. The coordinator
/// gives each worker thread its own backend + preparation.
pub trait PreparedSvm {
    /// Solve the reduction SVM at budget `t` and regularization `C`.
    fn solve(&mut self, t: f64, c: f64, warm: Option<&SvmWarm>) -> anyhow::Result<SvmSolve>;
    /// Which formulation this preparation uses.
    fn mode(&self) -> SvmMode;
}

/// An SVM solving engine SVEN can drive (thread-local; see
/// [`PreparedSvm`] for the threading contract).
pub trait SvmBackend {
    fn name(&self) -> &str;
    /// Prepare `x` (n × p, dense or sparse) / `y` for repeated solves.
    /// The preparation owns its data and caches (gram blocks, staged
    /// device buffers), so it can outlive the borrow — workers cache one
    /// per data set.
    fn prepare(
        &self,
        x: &Design,
        y: &[f64],
        mode: SvmMode,
    ) -> anyhow::Result<Box<dyn PreparedSvm>>;
}

/// In-process Newton backend ("SVEN (CPU)").
#[derive(Clone, Debug)]
pub struct RustBackend {
    pub primal: PrimalOptions,
    pub dual: DualOptions,
}

impl Default for RustBackend {
    fn default() -> Self {
        RustBackend { primal: PrimalOptions::default(), dual: DualOptions::default() }
    }
}

impl SvmBackend for RustBackend {
    fn name(&self) -> &str {
        "rust-newton"
    }

    fn prepare(
        &self,
        x: &Design,
        y: &[f64],
        mode: SvmMode,
    ) -> anyhow::Result<Box<dyn PreparedSvm>> {
        let (n, p) = (x.rows(), x.cols());
        match mode.resolve(n, p) {
            SvmMode::Primal => Ok(Box::new(PreparedPrimal {
                opts: self.primal.clone(),
                x: x.clone(),
                y: y.to_vec(),
            })),
            SvmMode::Dual => Ok(Box::new(PreparedDual {
                opts: self.dual.clone(),
                // t-independent gram pieces, computed once: dense designs
                // use the packed blocked kernel, sparse designs the
                // threaded CSR/CSC join — either way G₀ is p × p.
                g0: x.gram_t(),
                v: x.matvec_t(y),
                yy: vecops::norm2_sq(y),
                x: x.clone(),
                y: y.to_vec(),
            })),
            SvmMode::Auto => unreachable!(),
        }
    }
}

struct PreparedPrimal {
    opts: PrimalOptions,
    x: Design,
    y: Vec<f64>,
}

impl PreparedSvm for PreparedPrimal {
    fn solve(&mut self, t: f64, c: f64, warm: Option<&SvmWarm>) -> anyhow::Result<SvmSolve> {
        let samples = ReducedSamples { x: &self.x, y: &self.y, t };
        let labels = reduction_labels(self.x.cols());
        let w0 = warm.and_then(|w| w.w.as_deref());
        let r = primal_newton(&samples, &labels, c, &self.opts, w0);
        Ok(SvmSolve { alpha: r.alpha, w: Some(r.w), iters: r.newton_iters })
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Primal
    }
}

struct PreparedDual {
    opts: DualOptions,
    g0: Mat,
    v: Vec<f64>,
    yy: f64,
    x: Design,
    y: Vec<f64>,
}

impl PreparedDual {
    /// Assemble K(t) from the cached, t-independent blocks in O(p²),
    /// row-parallel over the scoped pool.
    fn gram_at(&self, t: f64) -> Mat {
        let p = self.g0.rows();
        let s = 1.0 / t;
        let mut k = Mat::zeros(2 * p, 2 * p);
        crate::solvers::svm::samples::assemble_reduction_gram(
            &self.g0,
            &self.v,
            s,
            s * s * self.yy,
            &mut k,
        );
        k
    }
}

impl PreparedSvm for PreparedDual {
    fn solve(&mut self, t: f64, c: f64, warm: Option<&SvmWarm>) -> anyhow::Result<SvmSolve> {
        let k = self.gram_at(t);
        let warm_alpha = warm.and_then(|w| w.alpha.as_deref());
        let r = dual_newton(&k, c, &self.opts, warm_alpha);
        // w = Ẑα is cheap and useful for warm starts: Ẑ = [X̂₁, −X̂₂]
        let p = self.x.cols();
        let samples = ReducedSamples { x: &self.x, y: &self.y, t };
        let mut signed = r.alpha.clone();
        for v in signed[p..].iter_mut() {
            *v = -*v;
        }
        let mut w = vec![0.0; self.x.rows()];
        samples.matvec_t(&signed, &mut w);
        Ok(SvmSolve { alpha: r.alpha, w: Some(w), iters: r.pivots })
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Dual
    }
}

/// Validate that `reduction_gram` and the cached-block assembly agree —
/// exposed for tests and the runtime's own cross-checks.
pub fn gram_assembly_check(x: &Mat, y: &[f64], t: f64) -> f64 {
    let direct = reduction_gram(x, y, t);
    let design: Design = x.clone().into();
    let prep = PreparedDual {
        opts: DualOptions::default(),
        g0: design.gram_t(),
        v: design.matvec_t(y),
        yy: vecops::norm2_sq(y),
        x: design,
        y: y.to_vec(),
    };
    let assembled = prep.gram_at(t);
    let mut max = 0.0f64;
    for i in 0..direct.rows() {
        for j in 0..direct.cols() {
            max = max.max((direct.get(i, j) - assembled.get(i, j)).abs());
        }
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn mode_resolution() {
        assert_eq!(SvmMode::Auto.resolve(10, 20), SvmMode::Primal); // 2p=40 > n=10
        assert_eq!(SvmMode::Auto.resolve(100, 20), SvmMode::Dual); // 2p=40 ≤ 100
        assert_eq!(SvmMode::Primal.resolve(100, 20), SvmMode::Primal);
        assert_eq!(SvmMode::Dual.resolve(10, 20), SvmMode::Dual);
    }

    #[test]
    fn gram_assembly_matches_direct() {
        let mut rng = Rng::seed_from(161);
        let x = Mat::from_fn(12, 5, |_, _| rng.normal());
        let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        for t in [0.1, 1.0, 10.0] {
            let dev = gram_assembly_check(&x, &y, t);
            assert!(dev < 1e-9, "t={t} dev={dev}");
        }
    }

    #[test]
    fn primal_dual_same_alpha_up_to_scale() {
        let mut rng = Rng::seed_from(162);
        let x: Design = Mat::from_fn(30, 6, |_, _| rng.normal()).into();
        let y: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let backend = RustBackend::default();
        let mut prim = backend.prepare(&x, &y, SvmMode::Primal).unwrap();
        let mut dual = backend.prepare(&x, &y, SvmMode::Dual).unwrap();
        let (t, c) = (0.8, 5.0);
        let a = prim.solve(t, c, None).unwrap().alpha;
        let b = dual.solve(t, c, None).unwrap().alpha;
        for i in 0..12 {
            assert!((a[i] - b[i]).abs() < 1e-5, "i={i}: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn sparse_and_dense_preparations_agree() {
        // A sparse Design must produce the same SVM solution as its
        // densified twin, in both modes.
        let mut rng = Rng::seed_from(163);
        let m = Mat::from_fn(40, 9, |_, _| {
            if rng.bernoulli(0.25) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let dense: Design = m.clone().into();
        let sparse: Design = crate::linalg::Csr::from_dense(&m, 0.0).into();
        let backend = RustBackend::default();
        for mode in [SvmMode::Primal, SvmMode::Dual] {
            let mut pd = backend.prepare(&dense, &y, mode).unwrap();
            let mut ps = backend.prepare(&sparse, &y, mode).unwrap();
            let a = pd.solve(0.7, 4.0, None).unwrap().alpha;
            let b = ps.solve(0.7, 4.0, None).unwrap().alpha;
            for i in 0..18 {
                assert!(
                    (a[i] - b[i]).abs() < 1e-6,
                    "{mode:?} i={i}: {} vs {}",
                    a[i],
                    b[i]
                );
            }
        }
    }
}
