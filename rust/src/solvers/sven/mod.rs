//! SVEN — the paper's contribution: solve the Elastic Net by reducing it
//! to a squared-hinge SVM (Algorithm 1 of the paper).
//!
//! ```text
//! 1.  X̂₁ = X − y·1ᵀ/t,  X̂₂ = X + y·1ᵀ/t            (implicit here)
//! 2.  SVM samples: columns of [X̂₁, X̂₂]; labels +1 (first p), −1 (rest)
//! 3.  C = 1/(2λ₂)
//! 4.  if 2p > n: primal solve for w;  α = 2C·max(1 − ŷ∘(X̂w), 0)
//!     else:      dual solve for α over K = ẐᵀẐ
//! 5.  β = t·(α₁..p − α_{p+1..2p}) / Σᵢαᵢ
//! ```
//!
//! The SVM step is pluggable through [`SvmBackend`]:
//! [`backend::RustBackend`] is the in-process Newton solver
//! ("SVEN (CPU)"); [`crate::runtime::XlaBackend`] executes the
//! AOT-compiled JAX/Pallas artifacts via PJRT ("SVEN (XLA)", standing in
//! for the paper's "SVEN (GPU)").

pub mod backend;
pub mod reduction;

pub use backend::{
    RustBackend, SvmBackend, SvmBatchStats, SvmMode, SvmPrep, SvmScratch, SvmSolve, SvmWarm,
};
pub use reduction::{backmap, effective_c, MIN_ALPHA_SUM};

use crate::linalg::{
    with_kernel_choice, with_precision, AsDesign, Design, KernelChoice, Precision,
};
use crate::solvers::elastic_net::{EnProblem, EnSolution, EnSolverKind};
use crate::solvers::svm::SolveCtl;
use crate::util::parallel::{with_parallelism, Parallelism};
use crate::util::Timer;
use std::sync::Arc;

/// SVEN configuration.
#[derive(Clone, Debug)]
pub struct SvenConfig {
    /// Force primal/dual instead of the 2p > n rule.
    pub mode: SvmMode,
    /// Cap on C when λ₂ → 0 (the paper's "treat Lasso as hard-margin"
    /// advice, made numerical): C = min(1/(2λ₂), c_cap). At C beyond
    /// ~1e6 the slacks 1 − ŷ·(X̂w) underflow into cancellation noise in
    /// f64, so the cap trades an O(1/C) ridge perturbation for numerical
    /// stability — the same trade the paper makes by special-casing the
    /// hard-margin solver.
    pub c_cap: f64,
    /// Worker-thread policy for the blocked linalg kernels under this
    /// solver (gram builds, Newton Hessian products, K assembly). The
    /// kernels are bit-stable across settings, so this is purely a
    /// performance knob; `Auto` defers to the process default /
    /// `PALLAS_NUM_THREADS`.
    pub parallelism: Parallelism,
    /// Microkernel policy for the same kernels (next to `parallelism`):
    /// force `scalar`/`avx2`/`fma`, or `Auto` to defer to the process
    /// default / `PALLAS_KERNEL` / CPU detection. Unlike the thread
    /// knob this *can* move result bits (FMA rounds differently), which
    /// is exactly why it is a first-class setting; forcing a kernel the
    /// CPU cannot run fails the solve with a clear error.
    pub kernel: KernelChoice,
    /// Compute-precision policy for the primal Newton's panel products
    /// (third knob next to `parallelism` and `kernel`): `F64` is the
    /// reference tier, `MixedF32` streams the bandwidth-bound panels in
    /// f32 with an f64 iterative-refinement loop restoring the full
    /// CG tolerance, and `Auto` defers to the process default /
    /// `PALLAS_PRECISION`. Resolved once at prep time — a preparation is
    /// pinned to its tier. The dual backend ignores `MixedF32` for now
    /// (f64 Cholesky; see ROADMAP).
    pub precision: Precision,
}

impl Default for SvenConfig {
    fn default() -> Self {
        SvenConfig {
            mode: SvmMode::Auto,
            c_cap: 1e6,
            parallelism: Parallelism::Auto,
            kernel: KernelChoice::Auto,
            precision: Precision::Auto,
        }
    }
}

/// The SVEN solver over a pluggable SVM backend.
pub struct Sven<B: SvmBackend> {
    pub backend: B,
    pub config: SvenConfig,
}

impl<B: SvmBackend> Sven<B> {
    pub fn new(backend: B) -> Self {
        Sven { backend, config: SvenConfig::default() }
    }

    pub fn with_config(backend: B, config: SvenConfig) -> Self {
        Sven { backend, config }
    }

    /// Run `f` under this config's kernel + precision + parallelism
    /// scopes (an unsupported forced kernel surfaces here, before any
    /// work runs).
    fn scoped<T>(&self, f: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
        match with_kernel_choice(self.config.kernel, || {
            with_precision(self.config.precision, || {
                with_parallelism(self.config.parallelism, f)
            })
        }) {
            Ok(res) => res,
            Err(e) => Err(anyhow::Error::from(e)),
        }
    }

    /// One-shot solve of a single Elastic Net problem. The problem's
    /// shared data feeds preparation directly — no copies.
    pub fn solve(&self, prob: &EnProblem) -> anyhow::Result<EnSolution> {
        let prepared = self.prepare_shared(&prob.x, &prob.y)?;
        let mut scratch = SvmScratch::new();
        self.solve_prepared(prepared.as_ref(), &mut scratch, prob, None, None)
    }

    /// Solve with a prepared problem (gram/caches reused across path
    /// points), a per-thread scratch, and an optional warm start from the
    /// previous point. The preparation is shared (`&dyn SvmPrep`, often
    /// behind an `Arc` owned by a cache); all mutable state lives in
    /// `scratch`.
    pub fn solve_prepared(
        &self,
        prepared: &dyn SvmPrep,
        scratch: &mut SvmScratch,
        prob: &EnProblem,
        warm: Option<&SvmWarm>,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<EnSolution> {
        let timer = Timer::start();
        let p = prob.p();
        let c = effective_c(prob.lambda2, self.config.c_cap);
        let solve = self.scoped(|| prepared.solve(prob.t, c, warm, scratch, ctl))?;
        let (beta, degenerate) = backmap(&solve.alpha, p, prob.t);
        let seconds = timer.elapsed();
        let objective = prob.objective(&beta);
        Ok(EnSolution {
            beta,
            solver: self.kind(),
            objective,
            iterations: solve.iters,
            cg_iters: solve.cg_iters,
            gather_rebuilds: solve.gather_rebuilds,
            refine_passes: solve.refine_passes,
            seconds,
            degenerate,
            aborted: solve.aborted,
            broken: solve.broken,
        })
    }

    /// Response-override form of [`Sven::solve_prepared`]: solve
    /// `prob` (whose response may differ from the one the preparation
    /// was built on) against `prepared`'s y-independent caches. Bit-for-
    /// bit what a fresh preparation of `(prob.x, prob.y)` would produce
    /// with the same warm start — the dual regime's multi-response
    /// sweep chains per-response warm starts through this.
    pub fn solve_prepared_response(
        &self,
        prepared: &dyn SvmPrep,
        scratch: &mut SvmScratch,
        prob: &EnProblem,
        warm: Option<&SvmWarm>,
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<EnSolution> {
        let timer = Timer::start();
        let p = prob.p();
        let c = effective_c(prob.lambda2, self.config.c_cap);
        let solve = self.scoped(|| {
            prepared.solve_response(prob.y.as_slice(), prob.t, c, warm, scratch, ctl)
        })?;
        let (beta, degenerate) = backmap(&solve.alpha, p, prob.t);
        let seconds = timer.elapsed();
        let objective = prob.objective(&beta);
        Ok(EnSolution {
            beta,
            solver: self.kind(),
            objective,
            iterations: solve.iters,
            cg_iters: solve.cg_iters,
            gather_rebuilds: solve.gather_rebuilds,
            refine_passes: solve.refine_passes,
            seconds,
            degenerate,
            aborted: solve.aborted,
            broken: solve.broken,
        })
    }

    /// Batched form of [`Sven::solve_prepared`]: solve every `(t, λ₂)`
    /// point of `points` against one preparation, cold-started — exactly
    /// what a primal-mode path sweep does anyway (its chained warm
    /// starts carry only dual variables, which the primal solver
    /// ignores), so the fused solve is bit-for-bit the sequential
    /// chain's. Returns the per-point solutions plus the batch's fusion
    /// stats; `seconds` is the batch total amortized per point.
    pub fn solve_prepared_batch(
        &self,
        prepared: &dyn SvmPrep,
        scratch: &mut SvmScratch,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
        points: &[(f64, f64)],
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<EnSolution>, SvmBatchStats)> {
        let timer = Timer::start();
        let pts: Vec<(f64, f64)> = points
            .iter()
            .map(|&(t, lambda2)| (t, effective_c(lambda2, self.config.c_cap)))
            .collect();
        let (solves, stats) = self.scoped(|| prepared.solve_batch(&pts, scratch, ctl))?;
        let per_point = if points.is_empty() {
            0.0
        } else {
            timer.elapsed() / points.len() as f64
        };
        let mut out = Vec::with_capacity(points.len());
        for (solve, &(t, lambda2)) in solves.into_iter().zip(points) {
            let prob = EnProblem::shared(x.clone(), y.clone(), t, lambda2);
            let (beta, degenerate) = backmap(&solve.alpha, prob.p(), t);
            let objective = prob.objective(&beta);
            out.push(EnSolution {
                beta,
                solver: self.kind(),
                objective,
                iterations: solve.iters,
                cg_iters: solve.cg_iters,
                gather_rebuilds: solve.gather_rebuilds,
                refine_passes: solve.refine_passes,
                seconds: per_point,
                degenerate,
                aborted: solve.aborted,
                broken: solve.broken,
            });
        }
        Ok((out, stats))
    }

    /// Multi-response form of [`Sven::solve_prepared_batch`]: member
    /// `(r, t, λ₂)` solves response `responses[r]` at `(t, λ₂)` against
    /// one shared preparation — the response dimension rides the same
    /// batch width as path points, so R responses at one grid point
    /// share the gathered SV panel and the blocked-CG panel product.
    /// Every member is bit-for-bit what a standalone cold solve of
    /// `(x, responses[r], t, λ₂)` produces (pinned in `backend` tests).
    pub fn solve_prepared_batch_multi(
        &self,
        prepared: &dyn SvmPrep,
        scratch: &mut SvmScratch,
        x: &Arc<Design>,
        responses: &[Arc<Vec<f64>>],
        members: &[(usize, f64, f64)],
        ctl: Option<&SolveCtl>,
    ) -> anyhow::Result<(Vec<EnSolution>, SvmBatchStats)> {
        let timer = Timer::start();
        let pts: Vec<(usize, f64, f64)> = members
            .iter()
            .map(|&(r, t, lambda2)| (r, t, effective_c(lambda2, self.config.c_cap)))
            .collect();
        let (solves, stats) =
            self.scoped(|| prepared.solve_batch_multi(responses, &pts, scratch, ctl))?;
        let per_member = if members.is_empty() {
            0.0
        } else {
            timer.elapsed() / members.len() as f64
        };
        let mut out = Vec::with_capacity(members.len());
        for (solve, &(r, t, lambda2)) in solves.into_iter().zip(members) {
            let prob = EnProblem::shared(x.clone(), responses[r].clone(), t, lambda2);
            let (beta, degenerate) = backmap(&solve.alpha, prob.p(), t);
            let objective = prob.objective(&beta);
            out.push(EnSolution {
                beta,
                solver: self.kind(),
                objective,
                iterations: solve.iters,
                cg_iters: solve.cg_iters,
                gather_rebuilds: solve.gather_rebuilds,
                refine_passes: solve.refine_passes,
                seconds: per_member,
                degenerate,
                aborted: solve.aborted,
                broken: solve.broken,
            });
        }
        Ok((out, stats))
    }

    fn kind(&self) -> EnSolverKind {
        if self.backend.name().contains("xla") {
            EnSolverKind::SvenXla
        } else {
            EnSolverKind::SvenCpu
        }
    }

    /// Prepare a dataset once for repeated (t, λ₂) solves. Accepts a bare
    /// `Mat`, a `Csr`, or an existing [`Design`] (see [`AsDesign`]);
    /// sparse designs are prepared without densifying. This convenience
    /// form wraps the data into fresh `Arc`s (one copy at the boundary);
    /// hot paths holding shared data should call [`Sven::prepare_shared`].
    pub fn prepare(
        &self,
        x: &impl AsDesign,
        y: &[f64],
    ) -> anyhow::Result<Arc<dyn SvmPrep>> {
        let design = Arc::new(x.as_design().into_owned());
        let y = Arc::new(y.to_vec());
        self.prepare_shared(&design, &y)
    }

    /// Zero-copy preparation over already-shared data: the preparation
    /// holds `Arc` clones of `x`/`y`, never a deep copy.
    pub fn prepare_shared(
        &self,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
    ) -> anyhow::Result<Arc<dyn SvmPrep>> {
        self.scoped(|| self.backend.prepare(x, y, self.config.mode))
    }

    /// Degeneracy pre-check (paper §3): if `t` exceeds the L1 norm of the
    /// ridge solution, the constraint is slack and the reduction's
    /// tightness assumption fails. O(min(n,p)³) — optional, for warnings.
    pub fn budget_is_slack(&self, prob: &EnProblem) -> bool {
        ridge_l1_norm(&prob.x, &prob.y, prob.lambda2) <= prob.t
    }
}

/// |β_ridge|₁ for the slack-budget detector: solves
/// (XᵀX + λ₂I)β = Xᵀy via the smaller-side normal equations. The gram of
/// the smaller side is a dense min(n,p)² output either way; sparse
/// designs assemble it through the CSR/CSC join instead of densifying X.
fn ridge_l1_norm(x: &Design, y: &[f64], lambda2: f64) -> f64 {
    use crate::linalg::{vecops, Cholesky};
    let (n, p) = (x.rows(), x.cols());
    let l2 = lambda2.max(1e-8);
    let beta = if p <= n {
        // (XᵀX + λI) β = Xᵀy
        let mut g = x.gram_t();
        for i in 0..p {
            let v = g.get(i, i) + l2;
            g.set(i, i, v);
        }
        let xty = x.matvec_t(y);
        match Cholesky::factor_ridged(&g, 1e-10, 8) {
            Ok(ch) => ch.solve(&xty),
            Err(_) => return f64::INFINITY,
        }
    } else {
        // β = Xᵀ(XXᵀ + λI)⁻¹ y
        let mut g = x.gram();
        for i in 0..n {
            let v = g.get(i, i) + l2;
            g.set(i, i, v);
        }
        match Cholesky::factor_ridged(&g, 1e-10, 8) {
            Ok(ch) => {
                let u = ch.solve(y);
                x.matvec_t(&u)
            }
            Err(_) => return f64::INFINITY,
        }
    };
    vecops::norm1(&beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_regression, SynthSpec};
    use crate::linalg::Mat;
    use crate::solvers::glmnet::{self, GlmnetConfig, PathSettings};

    fn dataset(n: usize, p: usize, seed: u64) -> (Mat, Vec<f64>) {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: p.min(6),
            seed,
            ..Default::default()
        });
        (d.x, d.y)
    }

    /// The headline correctness property: SVEN(t=|β*|₁, λ₂=nλ(1−κ)) must
    /// reproduce the glmnet solution β*.
    fn check_matches_glmnet(n: usize, p: usize, seed: u64, kappa: f64) {
        let (x, y) = dataset(n, p, seed);
        let lambda = glmnet::cd::lambda_max(&x, &y, kappa) * 0.3;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa, tol: 1e-13, ..Default::default() },
            None,
        );
        let t = crate::solvers::elastic_net::budget_from_beta(&g.beta);
        if t <= 1e-12 {
            return; // fully sparse solution; nothing to compare
        }
        let lambda2 = n as f64 * lambda * (1.0 - kappa);
        let prob = EnProblem::new(x, y, t, lambda2);
        let sven = Sven::new(RustBackend::default());
        let sol = sven.solve(&prob).unwrap();
        assert!(sol.degenerate.is_none(), "unexpected degeneracy");
        for j in 0..p {
            assert!(
                (sol.beta[j] - g.beta[j]).abs() < 5e-5,
                "{n}x{p} seed {seed} κ={kappa} j={j}: sven {} vs glmnet {}",
                sol.beta[j],
                g.beta[j]
            );
        }
    }

    #[test]
    fn matches_glmnet_p_gg_n() {
        check_matches_glmnet(20, 80, 151, 0.5); // dual side: n < 2p... (2p=160 > 20 ⇒ primal)
        check_matches_glmnet(15, 60, 152, 0.7);
    }

    #[test]
    fn matches_glmnet_n_gg_p() {
        check_matches_glmnet(200, 10, 153, 0.5); // n=200 ≥ 2p=20 ⇒ dual mode
        check_matches_glmnet(150, 8, 154, 0.3);
    }

    #[test]
    fn primal_and_dual_agree() {
        let (x, y) = dataset(60, 25, 155);
        let pts = glmnet::compute_path(
            &x,
            &y,
            &PathSettings { num_lambda: 20, ..Default::default() },
        );
        let pt = pts.iter().find(|pt| pt.nnz >= 3).expect("active point");
        let prob = EnProblem::new(x.clone(), y.clone(), pt.t, pt.lambda2.max(1e-3));
        let sp = Sven::with_config(
            RustBackend::default(),
            SvenConfig { mode: SvmMode::Primal, ..Default::default() },
        );
        let sd = Sven::with_config(
            RustBackend::default(),
            SvenConfig { mode: SvmMode::Dual, ..Default::default() },
        );
        let bp = sp.solve(&prob).unwrap().beta;
        let bd = sd.solve(&prob).unwrap().beta;
        for j in 0..25 {
            assert!((bp[j] - bd[j]).abs() < 1e-5, "j={j}: {} vs {}", bp[j], bd[j]);
        }
    }

    #[test]
    fn lasso_limit_small_lambda2() {
        // λ₂ = 0 (Lasso): C is capped at c_cap, i.e. SVEN actually solves
        // the EN with λ₂ = 1/(2·c_cap) — an O(1/C) perturbation of the
        // Lasso. Compare against glmnet with tolerance matched to that
        // perturbation rather than the exact-equality tolerance.
        let (x, y) = dataset(30, 50, 156);
        let lambda = glmnet::cd::lambda_max(&x, &y, 1.0) * 0.3;
        let g = glmnet::solve_penalized(
            &x,
            &y,
            lambda,
            &GlmnetConfig { kappa: 1.0, tol: 1e-13, ..Default::default() },
            None,
        );
        let t = crate::solvers::elastic_net::budget_from_beta(&g.beta);
        let prob = EnProblem::new(x.clone(), y.clone(), t, 0.0);
        let sven = Sven::new(RustBackend::default());
        let sol = sven.solve(&prob).unwrap();
        // Objectives (λ₂ = 0 form) must agree closely even if individual
        // coordinates differ when the Lasso optimum is nearly degenerate.
        let obj = |b: &[f64]| {
            let mut r = x.matvec(b);
            crate::linalg::vecops::axpy(-1.0, &y, &mut r);
            crate::linalg::vecops::norm2_sq(&r)
        };
        let og = obj(&g.beta);
        let os = obj(&sol.beta);
        assert!(
            (os - og).abs() <= 1e-3 * (1.0 + og.abs()),
            "objective: sven {os} vs glmnet {og}"
        );
        for j in 0..50 {
            assert!(
                (sol.beta[j] - g.beta[j]).abs() < 5e-3,
                "j={j}: {} vs {}",
                sol.beta[j],
                g.beta[j]
            );
        }
    }

    #[test]
    fn l1_budget_is_respected() {
        let (x, y) = dataset(40, 30, 157);
        let pts = glmnet::compute_path(
            &x,
            &y,
            &PathSettings { num_lambda: 25, ..Default::default() },
        );
        let pt = pts.iter().find(|pt| pt.nnz >= 2).unwrap();
        let prob = EnProblem::new(x, y, pt.t, pt.lambda2.max(1e-3));
        let sven = Sven::new(RustBackend::default());
        let sol = sven.solve(&prob).unwrap();
        let l1: f64 = sol.beta.iter().map(|b| b.abs()).sum();
        assert!(l1 <= prob.t * (1.0 + 1e-6), "|β|₁ = {l1} > t = {}", prob.t);
        // and the constraint is tight (non-degenerate case)
        assert!(l1 >= prob.t * (1.0 - 1e-6), "|β|₁ = {l1} ≪ t = {}", prob.t);
    }

    #[test]
    fn forced_scalar_kernel_matches_auto() {
        let (x, y) = dataset(40, 25, 161);
        let prob = EnProblem::new(x, y, 0.2, 0.5);
        let auto = Sven::new(RustBackend::default());
        let forced = Sven::with_config(
            RustBackend::default(),
            SvenConfig { kernel: KernelChoice::Scalar, ..Default::default() },
        );
        let ba = auto.solve(&prob).unwrap().beta;
        let bs = forced.solve(&prob).unwrap().beta;
        // Different kernels may round differently; the solves must still
        // land on the same optimum to solver tolerance.
        for j in 0..25 {
            assert!((ba[j] - bs[j]).abs() < 1e-6, "j={j}: {} vs {}", ba[j], bs[j]);
        }
    }

    #[test]
    fn slack_budget_detector() {
        let (x, y) = dataset(50, 5, 158);
        // huge budget ⇒ ridge regime
        let prob = EnProblem::new(x.clone(), y.clone(), 1e6, 1.0);
        let sven = Sven::new(RustBackend::default());
        assert!(sven.budget_is_slack(&prob));
        // tiny budget ⇒ tight
        let prob2 = EnProblem::new(x, y, 1e-3, 1.0);
        assert!(!sven.budget_is_slack(&prob2));
    }

    #[test]
    fn prepared_reuse_matches_oneshot() {
        let (x, y) = dataset(80, 12, 159);
        let pts = glmnet::compute_path(
            &x,
            &y,
            &PathSettings { num_lambda: 30, ..Default::default() },
        );
        let active: Vec<_> = pts.iter().filter(|pt| pt.nnz > 0).take(5).collect();
        let sven = Sven::new(RustBackend::default());
        let prep = sven.prepare(&x, &y).unwrap();
        let mut scratch = SvmScratch::new();
        let mut warm: Option<SvmWarm> = None;
        for pt in active {
            let prob = EnProblem::new(x.clone(), y.clone(), pt.t, pt.lambda2.max(1e-4));
            let via_prep = sven
                .solve_prepared(prep.as_ref(), &mut scratch, &prob, warm.as_ref(), None)
                .unwrap();
            let oneshot = sven.solve(&prob).unwrap();
            for j in 0..12 {
                assert!(
                    (via_prep.beta[j] - oneshot.beta[j]).abs() < 1e-6,
                    "t={} j={j}",
                    pt.t
                );
            }
            warm = Some(SvmWarm::default());
        }
    }
}
