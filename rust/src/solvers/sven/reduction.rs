//! The reduction arithmetic: C from λ₂, and the α → β back-map
//! (Algorithm 1 lines 3 and 11).

use crate::solvers::elastic_net::Degenerate;

/// Below this total dual mass the SVM "selected no support vectors"
/// (paper footnote 1) and the back-map is undefined; we return β = 0.
pub const MIN_ALPHA_SUM: f64 = 1e-12;

/// `C = 1/(2λ₂)`, capped for the Lasso limit λ₂ → 0 (paper §3 suggests a
/// hard-margin special case; a large finite C is its numerical twin).
pub fn effective_c(lambda2: f64, c_cap: f64) -> f64 {
    if lambda2 <= 0.0 {
        c_cap
    } else {
        (1.0 / (2.0 * lambda2)).min(c_cap)
    }
}

/// `β = t·(α₁..p − α_{p+1..2p}) / Σᵢ αᵢ` — scale-invariant in α.
pub fn backmap(alpha: &[f64], p: usize, t: f64) -> (Vec<f64>, Option<Degenerate>) {
    assert_eq!(alpha.len(), 2 * p, "alpha must have length 2p");
    let sum: f64 = alpha.iter().sum();
    if sum <= MIN_ALPHA_SUM {
        return (vec![0.0; p], Some(Degenerate::NoSupportVectors));
    }
    let scale = t / sum;
    let beta: Vec<f64> =
        (0..p).map(|i| scale * (alpha[i] - alpha[p + i])).collect();
    (beta, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c_mapping() {
        assert_eq!(effective_c(0.5, 1e10), 1.0);
        assert_eq!(effective_c(0.0, 1e10), 1e10);
        assert_eq!(effective_c(1e-20, 1e10), 1e10); // capped
    }

    #[test]
    fn backmap_basic() {
        // p = 2, α = [3, 0, 1, 0] ⇒ Σ = 4, β = t·[(3−1)/4, 0]
        let (beta, d) = backmap(&[3.0, 0.0, 1.0, 0.0], 2, 2.0);
        assert!(d.is_none());
        assert!((beta[0] - 1.0).abs() < 1e-15);
        assert_eq!(beta[1], 0.0);
    }

    #[test]
    fn backmap_scale_invariant() {
        let a = [0.2, 0.7, 0.1, 0.0];
        let (b1, _) = backmap(&a, 2, 1.5);
        let a_scaled: Vec<f64> = a.iter().map(|v| v * 37.0).collect();
        let (b2, _) = backmap(&a_scaled, 2, 1.5);
        for i in 0..2 {
            assert!((b1[i] - b2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn backmap_l1_norm_bounded_by_t() {
        // |β|₁ = t·Σ|αᵢ − α_{p+i}| / Σαᵢ ≤ t, with equality iff
        // complementary (αᵢ·α_{p+i} = 0 ∀i).
        let (beta, _) = backmap(&[1.0, 2.0, 0.5, 0.0], 2, 3.0);
        let l1: f64 = beta.iter().map(|b| b.abs()).sum();
        assert!(l1 <= 3.0 + 1e-12);
        // complementary case: exact
        let (beta2, _) = backmap(&[1.0, 0.0, 0.0, 2.0], 2, 3.0);
        let l1_2: f64 = beta2.iter().map(|b| b.abs()).sum();
        assert!((l1_2 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_zero_alpha() {
        let (beta, d) = backmap(&[0.0; 6], 3, 1.0);
        assert_eq!(d, Some(Degenerate::NoSupportVectors));
        assert_eq!(beta, vec![0.0; 3]);
    }
}
