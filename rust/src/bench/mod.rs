//! Benchmark harness: regenerates every figure of the paper's evaluation
//! (§5) plus our ablations. Criterion is unavailable offline, so this is
//! a self-contained harness with warmup, repetition and order statistics;
//! the `cargo bench` binaries in `rust/benches/` are thin wrappers over
//! [`figures`].
//!
//! Scaling: set `SVEN_BENCH_SCALE=full` for the full 40-setting grid of
//! the paper, or leave default (`quick`, 8 settings) for CI-sized runs.
//! Either way the *geometry* of the comparison (who wins, how timing
//! scales with t) is what the figures check.

pub mod figures;
pub mod harness;

pub use harness::{measure, BenchRow, Measurement};

/// Grid size per dataset, controlled by SVEN_BENCH_SCALE (quick|full).
pub fn grid_size() -> usize {
    match std::env::var("SVEN_BENCH_SCALE").as_deref() {
        Ok("full") => 40,
        Ok("mid") => 16,
        _ => 8,
    }
}

/// Dataset size multiplier for quick runs (full profiles are used for
/// `full`/`mid`; quick shrinks generation so a whole figure finishes in
/// minutes).
pub fn size_factor() -> f64 {
    match std::env::var("SVEN_BENCH_SCALE").as_deref() {
        Ok("full") => 1.0,
        Ok("mid") => 0.5,
        _ => 0.25,
    }
}
