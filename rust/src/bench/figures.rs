//! Figure regeneration logic shared by the `cargo bench` binaries.
//!
//! - [`figure1`] — regularization paths of glmnet vs SVEN on the
//!   prostate-like set; prints the per-budget β table for both solvers
//!   and the max deviation (paper Fig. 1: "the two algorithms match
//!   exactly for all values of t").
//! - [`figure2`] — training-time comparison on the eight p ≫ n profiles:
//!   glmnet, Shotgun, L1_LS, SVEN (CPU) against SVEN (XLA) per setting
//!   (paper Fig. 2 scatter, printed as rows + digest).
//! - [`figure3`] — same on the four n ≫ p profiles, where SVEN's time is
//!   dominated by the one-off gram computation (paper Fig. 3).
//! - [`ablations`] — design-choice studies DESIGN.md calls out: primal vs
//!   dual crossover, warm-start effect, bucket padding overhead, gram
//!   caching.

use super::harness::{print_table, BenchRow};
use crate::coordinator::{PathRunner, PathRunnerConfig};
use crate::data::{profiles, Dataset, DatasetProfile};
use crate::solvers::elastic_net::EnProblem;
use crate::solvers::glmnet::{self, GlmnetConfig, PathPoint, PathSettings};
use crate::solvers::l1ls::{solve_l1ls, L1LsConfig};
use crate::solvers::shotgun::{solve_shotgun, ShotgunConfig};
use crate::solvers::sven::{RustBackend, Sven, SvmScratch, SvmWarm};
use crate::util::Timer;

/// Generate a profile scaled by the bench size factor.
fn scaled_dataset(profile: &DatasetProfile, factor: f64, seed: u64) -> Dataset {
    let mut spec = crate::data::SynthSpec {
        name: profile.name.to_string(),
        n: ((profile.n as f64 * factor) as usize).max(24),
        p: ((profile.p as f64 * factor) as usize).max(16),
        support: profile.support.min(((profile.p as f64 * factor) as usize).max(4) / 2),
        rho: profile.rho,
        density: profile.density,
        snr: profile.snr,
        seed,
    };
    // keep the regime intact after scaling
    if profile.n > profile.p && spec.n <= spec.p {
        spec.n = spec.p * 2 + 1;
    }
    if profile.p > profile.n && spec.p <= spec.n {
        spec.p = spec.n * 2 + 1;
    }
    crate::data::synth_regression(&spec)
}

/// Build the evaluation grid for a dataset (paper protocol).
fn grid_for(data: &Dataset, grid: usize) -> Vec<PathPoint> {
    let runner = PathRunner::new(PathRunnerConfig {
        grid,
        path: PathSettings { num_lambda: 80, ..Default::default() },
        ..Default::default()
    });
    runner.derive_grid(data)
}

/// Try to build the XLA-backed SVEN; fall back with a notice.
fn xla_sven() -> Option<Sven<crate::runtime::XlaBackend>> {
    match crate::runtime::XlaBackend::from_default_dir() {
        Ok(b) => Some(Sven::new(b)),
        Err(e) => {
            eprintln!("[bench] SVEN (XLA) unavailable ({e}); build with `make artifacts`");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// Linalg kernel micro-bench (gemm/gram)
// ---------------------------------------------------------------------------

/// Gemm/gram micro-bench: the seed's naive serial kernels against the
/// blocked core of the ambient [`KernelCtx`] at one thread and at the
/// effective thread count. `full` runs the acceptance shapes (gemm
/// 1024³; gram `XᵀX` for X of n=4096, p=1024); otherwise tiny CI-smoke
/// shapes. Returns the (gemm, gram) speedups of the threaded blocked
/// kernel over naive.
pub fn linalg_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::linalg::{gemm, KernelCtx};
    use crate::util::parallel;

    let ctx = KernelCtx::current();
    let nt = parallel::effective_threads();
    let reps = if full { 3 } else { 2 };
    let mut rng = crate::rng::Rng::seed_from(4242);
    println!(
        "=== linalg micro: seed naive kernel vs blocked (nt = {nt}, kernel = {}) ===",
        ctx.kernel_name()
    );

    // --- GEMM ---
    let (m, k, n) = if full { (1024, 1024, 1024) } else { (160, 96, 128) };
    let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
    let mut c = vec![0.0; m * n];
    let flops = 2.0 * (m * k * n) as f64;
    let t_naive = measure(1, reps, || gemm::naive_matmul_into(&a, &b, &mut c, m, k, n))
        .summary
        .median();
    let t_b1 = measure(1, reps, || ctx.blocked_matmul_into(&a, &b, &mut c, m, k, n, 1))
        .summary
        .median();
    let t_bn = measure(1, reps, || ctx.blocked_matmul_into(&a, &b, &mut c, m, k, n, nt))
        .summary
        .median();
    let gemm_speedup = t_naive / t_bn;
    println!(
        "gemm {m}x{k}x{n}: naive {:.1}ms ({:.2} GF/s) | blocked@1 {:.1}ms ({:.1}x) | \
         blocked@{nt} {:.1}ms ({:.1}x)",
        t_naive * 1e3,
        flops / t_naive / 1e9,
        t_b1 * 1e3,
        t_naive / t_b1,
        t_bn * 1e3,
        gemm_speedup
    );

    // --- Gram (XᵀX of an n×p design, computed as AAᵀ of the transpose) ---
    let (gm, gk) = if full { (1024, 4096) } else { (96, 200) };
    let a2: Vec<f64> = (0..gm * gk).map(|_| rng.normal()).collect();
    let mut g = vec![0.0; gm * gm];
    let gflops = (gm * gm * gk) as f64;
    let t_naive = measure(1, reps, || gemm::naive_gram_into(&a2, &mut g, gm, gk))
        .summary
        .median();
    let t_b1 =
        measure(1, reps, || ctx.blocked_gram_into(&a2, &mut g, gm, gk, 1)).summary.median();
    let t_bn =
        measure(1, reps, || ctx.blocked_gram_into(&a2, &mut g, gm, gk, nt)).summary.median();
    let gram_speedup = t_naive / t_bn;
    println!(
        "gram XᵀX (X {gk}x{gm}): naive {:.1}ms ({:.2} GF/s) | blocked@1 {:.1}ms ({:.1}x) | \
         blocked@{nt} {:.1}ms ({:.1}x)",
        t_naive * 1e3,
        gflops / t_naive / 1e9,
        t_b1 * 1e3,
        t_naive / t_b1,
        t_bn * 1e3,
        gram_speedup
    );
    (gemm_speedup, gram_speedup)
}

// ---------------------------------------------------------------------------
// Microkernel dispatch bench (tile rooflines, forced-kernel gram)
// ---------------------------------------------------------------------------

/// Microkernel dispatch bench. Prints the dispatched kernel + probed
/// cache geometry, measures every enabled microkernel's in-L1 tile peak
/// (packed panels at the kernel's own `kc` — the roofline the blocked
/// core can approach), then times the gram acceptance shape (`XᵀX` for
/// X of n=4096, p=1024 when `full`) blocked serially under the forced
/// scalar kernel vs the dispatched (best SIMD) kernel. The two results
/// are also checked against each other numerically — per-kernel
/// bit-identity is the proptests' job; here only rounding may differ
/// (FMA fuses). Returns (SIMD-over-scalar gram speedup, dispatched
/// kernel's achieved fraction of its tile roofline).
pub fn kernel_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::linalg::{best_available, enabled_choices, KernelChoice, KernelCtx};

    println!("=== kernel micro: microkernel dispatch and tile rooflines ===");
    println!("dispatch: {}", KernelCtx::current().describe());
    let reps = if full { 5 } else { 2 };
    let mut rng = crate::rng::Rng::seed_from(5151);

    // --- per-kernel in-L1 tile peak ---
    // A tile call is 2·mr·nr·kc flops over panels that fit L1 by
    // construction (kc is derived from half of L1d), so its GFLOP/s is
    // the compute ceiling for that kernel on this machine.
    let mut peaks: Vec<(KernelChoice, f64)> = Vec::new();
    for choice in enabled_choices() {
        let ctx = KernelCtx::for_choice(choice).expect("enabled kernel");
        let kern = ctx.micro();
        let (mr, nr) = (kern.mr(), kern.nr());
        let kc = ctx.blocking().kc;
        let ap: Vec<f64> = (0..kc * mr).map(|_| rng.normal()).collect();
        let bp: Vec<f64> = (0..kc * nr).map(|_| rng.normal()).collect();
        let mut acc = vec![0.0f64; mr * nr];
        let inner = if full { 20_000usize } else { 400 };
        let t = measure(1, reps, || {
            for _ in 0..inner {
                kern.tile(&ap, &bp, kc, &mut acc);
            }
            std::hint::black_box(&mut acc);
        })
        .summary
        .median();
        let gflops = (2 * mr * nr * kc * inner) as f64 / t / 1e9;
        peaks.push((choice, gflops));
        println!("  {choice}({mr}x{nr}) tile peak @kc={kc}: {gflops:.2} GFLOP/s");
    }

    // --- gram acceptance shape: forced-scalar vs dispatched kernel ---
    let (gm, gk) = if full { (1024usize, 4096usize) } else { (96, 160) };
    let a: Vec<f64> = (0..gm * gk).map(|_| rng.normal()).collect();
    let gram_flops = (gm * gm * gk) as f64;
    let scalar = KernelCtx::for_choice(KernelChoice::Scalar).expect("scalar always enabled");
    let best = KernelCtx::for_choice(best_available()).expect("best kernel enabled");
    let mut g_scalar = vec![0.0; gm * gm];
    let t_scalar = measure(1, reps, || {
        scalar.blocked_gram_into(&a, &mut g_scalar, gm, gk, 1)
    })
    .summary
    .median();
    let mut g_best = vec![0.0; gm * gm];
    let t_best = measure(1, reps, || best.blocked_gram_into(&a, &mut g_best, gm, gk, 1))
        .summary
        .median();
    // Cross-kernel agreement (rounding-only differences allowed).
    for (i, (s, b)) in g_scalar.iter().zip(&g_best).enumerate() {
        let scale = 1.0f64.max(s.abs());
        assert!(
            (s - b).abs() <= 1e-10 * scale,
            "scalar vs {} gram diverged at flat {i}: {s} vs {b}",
            best.kernel_name()
        );
    }
    let gf_scalar = gram_flops / t_scalar / 1e9;
    let gf_best = gram_flops / t_best / 1e9;
    let best_peak = peaks
        .iter()
        .find(|(c, _)| *c == best.choice())
        .map(|(_, p)| *p)
        .unwrap_or(f64::NAN);
    let frac = gf_best / best_peak;
    println!(
        "gram XᵀX (X {gk}x{gm}) blocked@1: scalar {:.1}ms ({gf_scalar:.2} GF/s) | \
         {} {:.1}ms ({gf_best:.2} GF/s = {:.0}% of its {best_peak:.2} GF/s roofline)",
        t_scalar * 1e3,
        best.kernel_name(),
        t_best * 1e3,
        frac * 100.0
    );
    (t_scalar / t_best, frac)
}

// ---------------------------------------------------------------------------
// Sparse kernel micro-bench (spmv / sparse gram / sparse CD)
// ---------------------------------------------------------------------------

/// Sparse-path micro-bench at the paper's extreme-sparsity regime
/// (Dorothea / E2006-tfidf are ~1e-2 dense): times the threaded CSR
/// kernels against `Parallelism::None`, and a glmnet CD solve through
/// the sparse [`Design`](crate::linalg::Design) against the same solve
/// on the densified matrix. `full` runs the acceptance shape (n=8192,
/// p=4096, density 0.01); otherwise tiny CI-smoke shapes. Returns the
/// (spmv, gram) serial→threaded speedups.
pub fn sparse_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::linalg::{Csc, Csr, Design, Mat};
    use crate::util::parallel::{self, with_parallelism, Parallelism};

    let nt = parallel::effective_threads();
    let reps = if full { 9 } else { 2 };
    // The smoke shape is sized just past the sparse fan-out threshold
    // (nnz ≈ 22k > 2^14) so the threaded kernel branches — not only the
    // serial fallbacks — run under `-- --test` in CI.
    let (n, p, density) = if full { (8192usize, 4096usize, 0.01) } else { (1024, 220, 0.1) };
    println!("=== sparse micro: serial vs threaded CSR kernels (nt = {nt}) ===");

    // ~density·p draws per row (duplicates merged by from_triplets)
    // keeps generation O(nnz) instead of O(n·p) bernoullis.
    let per_row = ((p as f64 * density).round() as usize).max(1);
    let mut rng = crate::rng::Rng::seed_from(9393);
    let mut trip = Vec::with_capacity(n * per_row);
    for r in 0..n {
        for _ in 0..per_row {
            trip.push((r, rng.below(p), rng.normal()));
        }
    }
    let a = Csr::from_triplets(n, p, trip);
    let x: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    println!(
        "shape {n}x{p}, nnz {} (density {:.4})",
        a.nnz(),
        a.density()
    );

    // --- spmv (A·x and Aᵀ·u) ---
    let t_mv_1 =
        measure(1, reps, || with_parallelism(Parallelism::None, || a.matvec(&x)))
            .summary
            .median();
    let t_mv_n = measure(1, reps, || a.matvec(&x)).summary.median();
    let t_mvt_1 =
        measure(1, reps, || with_parallelism(Parallelism::None, || a.matvec_t(&u)))
            .summary
            .median();
    let t_mvt_n = measure(1, reps, || a.matvec_t(&u)).summary.median();
    let spmv_speedup = (t_mv_1 / t_mv_n).max(t_mvt_1 / t_mvt_n);
    println!(
        "spmv A·x: serial {:.3}ms | @{nt} {:.3}ms ({:.1}x)   Aᵀ·u: serial {:.3}ms | \
         @{nt} {:.3}ms ({:.1}x)",
        t_mv_1 * 1e3,
        t_mv_n * 1e3,
        t_mv_1 / t_mv_n,
        t_mvt_1 * 1e3,
        t_mvt_n * 1e3,
        t_mvt_1 / t_mvt_n
    );

    // --- sparse gram XᵀX (the SVEN dual block) + CSC construction ---
    let csc = Csc::from_csr(&a);
    let mut g = Mat::zeros(p, p);
    let t_g_1 = measure(1, reps, || {
        with_parallelism(Parallelism::None, || a.gram_into(&csc, &mut g))
    })
    .summary
    .median();
    let t_g_n = measure(1, reps, || a.gram_into(&csc, &mut g)).summary.median();
    let t_csc_1 =
        measure(1, reps, || with_parallelism(Parallelism::None, || Csc::from_csr(&a)))
            .summary
            .median();
    let t_csc_n = measure(1, reps, || Csc::from_csr(&a)).summary.median();
    let gram_speedup = t_g_1 / t_g_n;
    println!(
        "gram XᵀX: serial {:.3}ms | @{nt} {:.3}ms ({:.1}x)   csc-build: serial {:.3}ms | \
         @{nt} {:.3}ms ({:.1}x)",
        t_g_1 * 1e3,
        t_g_n * 1e3,
        gram_speedup,
        t_csc_1 * 1e3,
        t_csc_n * 1e3,
        t_csc_1 / t_csc_n
    );

    // --- sparse vs dense CD at the same penalized setting ---
    // y from a sparse planted model so the solve is non-trivial.
    let beta_true: Vec<f64> = (0..p)
        .map(|j| if j % (p / 16).max(1) == 0 { rng.normal() } else { 0.0 })
        .collect();
    let mut y = a.matvec(&beta_true);
    for v in y.iter_mut() {
        *v += 0.1 * rng.normal();
    }
    let design: Design = a.clone().into();
    let cfg = GlmnetConfig {
        kappa: 1.0,
        mode: glmnet::CdMode::Naive,
        max_epochs: if full { 60 } else { 200 },
        ..Default::default()
    };
    let lambda = glmnet::lambda_max_design(&design, &y, cfg.kappa) * 0.3;
    let cd_reps = if full { 3 } else { 2 };
    let t_cd_sparse = measure(1, cd_reps, || {
        glmnet::solve_penalized_design(&design, &y, lambda, &cfg, None)
    })
    .summary
    .median();
    let dense = a.to_dense();
    let t_cd_dense = measure(1, cd_reps, || {
        glmnet::solve_penalized(&dense, &y, lambda, &cfg, None)
    })
    .summary
    .median();
    println!(
        "glmnet CD {n}x{p}@{density}: dense {:.2}ms | sparse Design {:.2}ms ({:.1}x)",
        t_cd_dense * 1e3,
        t_cd_sparse * 1e3,
        t_cd_dense / t_cd_sparse
    );

    (spmv_speedup, gram_speedup)
}

// ---------------------------------------------------------------------------
// Coordinator service micro-bench (throughput + prep-cache hit rate)
// ---------------------------------------------------------------------------

/// Service-layer micro-bench: jobs/sec through the coordinator for
/// single-point jobs vs one `JobKind::Path` job over the same grid, with
/// the shared prep cache's hit rate. The point of the comparison: K point
/// jobs and one K-point path job do the same numerical work, but the
/// path job ships one request and chains warm starts — the paper's
/// amortized access pattern as a single service workload. `full` runs a
/// serving-sized shape; otherwise tiny CI-smoke shapes. Returns
/// (point_jobs_per_s, path_points_per_s).
pub fn service_micro(full: bool) -> (f64, f64) {
    use crate::coordinator::{BackendChoice, PoolConfig, Service, ServiceConfig};
    use std::sync::Arc;

    let (n, p, grid_n, repeat) = if full { (160, 1200, 24, 4) } else { (30, 60, 4, 2) };
    let workers = if full { 4 } else { 2 };
    println!("=== service micro: point jobs vs path job ({workers} workers) ===");
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("serve-{n}x{p}"),
        n,
        p,
        support: (p / 24).max(4),
        seed: 2024,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig {
        grid: grid_n,
        path: PathSettings { num_lambda: 40, ..Default::default() },
        ..Default::default()
    });
    let grid = runner.derive_grid(&data);
    if grid.is_empty() {
        println!("empty grid, skipping");
        return (f64::NAN, f64::NAN);
    }
    let points = runner.grid_points(&grid);
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers, queue_capacity: 64 },
        ..Default::default()
    });
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let y = Arc::new(data.y.clone());

    // --- point jobs: repeat × grid single-solve requests ---
    let timer = Timer::start();
    let mut rxs = Vec::with_capacity(repeat * points.len());
    for _ in 0..repeat {
        for gp in &points {
            let rx = service
                .submit_point(1, x.clone(), y.clone(), gp.t, gp.lambda2, BackendChoice::Rust)
                .expect("service accepting jobs");
            rxs.push(rx);
        }
    }
    let jobs = rxs.len();
    for rx in rxs {
        rx.recv().unwrap().result.expect("point solve");
    }
    let point_s = timer.elapsed();
    let point_rate = jobs as f64 / point_s;

    // --- one path job over the same grid (warm-start chained) ---
    let timer = Timer::start();
    let rx = service
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("service accepting jobs");
    let sols = rx.recv().unwrap().result.expect("path solve").expect_path();
    let path_s = timer.elapsed();
    let path_rate = sols.len() as f64 / path_s;

    let m = service.metrics();
    let lookups = m.prep_hits() + m.prep_builds();
    println!(
        "point jobs: {jobs} in {point_s:.3}s ({point_rate:.1} jobs/s) | \
         path job: {} points in {path_s:.3}s ({path_rate:.1} points/s)",
        sols.len()
    );
    println!(
        "prep cache: builds={} hits={} (hit rate {:.1}%) evictions={}",
        m.prep_builds(),
        m.prep_hits(),
        100.0 * m.prep_hits() as f64 / lookups.max(1) as f64,
        m.prep_evictions()
    );
    assert_eq!(m.prep_builds(), 1, "one dataset must build exactly one prep");
    service.shutdown();
    (point_rate, path_rate)
}

// ---------------------------------------------------------------------------
// Path-engine micro-bench (multi-RHS panels / gathered Newton / segments)
// ---------------------------------------------------------------------------

/// Path-engine micro-bench, three comparisons:
///
/// 1. banded GEMV × r right-hand sides vs one fused multi-RHS panel
///    product (`Mat::matvec_multi_into`) at panel widths 2/4/8;
/// 2. masked full-matrix primal Newton vs the active-set (shrinking)
///    Newton on a low-SV-fraction problem;
/// 3. one `JobKind::Path` sweep on a single worker vs the same grid
///    split into chained segments across 4 workers (speculative warm
///    starts) — with a bit-for-bit identity check between the two.
///
/// `full` runs the acceptance shapes; otherwise tiny CI-smoke shapes.
/// Returns the (panel, gathered-Newton, segmented-sweep) speedups.
pub fn path_micro(full: bool) -> (f64, f64, f64) {
    use super::harness::measure;
    use crate::coordinator::{BackendChoice, PoolConfig, Service, ServiceConfig};
    use crate::linalg::{Mat, MultiVec};
    use crate::solvers::svm::{primal_newton, DenseSamples, PrimalOptions, SampleSet};
    use crate::util::parallel::{self, Parallelism};
    use std::sync::Arc;

    let nt = parallel::effective_threads();
    let reps = if full { 5 } else { 2 };
    println!("=== path micro: multi-RHS / gathered Newton / segmented sweeps (nt = {nt}) ===");
    let mut rng = crate::rng::Rng::seed_from(7171);

    // --- 1) r single GEMVs vs one fused panel product ---
    let (gm, gk) = if full { (4096usize, 1024usize) } else { (600, 160) };
    let a = Mat::from_fn(gm, gk, |_, _| rng.normal());
    let mut panel_speedup = 0.0f64;
    for r in [2usize, 4, 8] {
        let xs = MultiVec::from_fn(gk, r, |_, _| rng.normal());
        let mut single_out = vec![0.0; gm];
        let t_single = measure(1, reps, || {
            for j in 0..r {
                a.matvec_into(xs.col(j), &mut single_out);
            }
        })
        .summary
        .median();
        let mut ys = MultiVec::zeros(gm, r);
        let t_multi =
            measure(1, reps, || a.matvec_multi_into(&xs, &mut ys)).summary.median();
        let speedup = t_single / t_multi;
        panel_speedup = panel_speedup.max(speedup);
        println!(
            "gemv {gm}x{gk} r={r}: {r} GEMVs {:.3}ms | fused panel {:.3}ms ({:.2}x)",
            t_single * 1e3,
            t_multi * 1e3,
            speedup
        );
    }

    // --- 2) masked vs gathered (shrinking) primal Newton ---
    // Two well-separated blobs: most samples end up outside the margin,
    // so the SV fraction is small and the gathered panel is tiny.
    let (sm_half, sd) = if full { (2000usize, 300usize) } else { (150, 40) };
    let mut x = Mat::zeros(2 * sm_half, sd);
    let mut y = vec![0.0; 2 * sm_half];
    for i in 0..2 * sm_half {
        let cls = if i < sm_half { 1.0 } else { -1.0 };
        y[i] = cls;
        for j in 0..sd {
            let center = if j == 0 { cls * 2.0 } else { 0.0 };
            x.set(i, j, center + 0.3 * rng.normal());
        }
    }
    let samples = DenseSamples { x };
    let c = 1.0;
    let masked_opts = PrimalOptions { shrink: false, ..Default::default() };
    let gathered_opts = PrimalOptions::default();
    let t_masked = measure(1, reps, || {
        primal_newton(&samples, &y, c, &masked_opts, None)
    })
    .summary
    .median();
    let t_gathered = measure(1, reps, || {
        primal_newton(&samples, &y, c, &gathered_opts, None)
    })
    .summary
    .median();
    let probe = primal_newton(&samples, &y, c, &gathered_opts, None);
    let sv_count = probe.alpha.iter().filter(|a| **a > 0.0).count();
    let sv_frac = sv_count as f64 / samples.m() as f64;
    let newton_speedup = t_masked / t_gathered;
    println!(
        "primal newton m={} d={sd} (sv-frac {:.2}, {} gathers): masked {:.2}ms | \
         gathered {:.2}ms ({:.2}x)",
        samples.m(),
        sv_frac,
        probe.gather_rebuilds,
        t_masked * 1e3,
        t_gathered * 1e3,
        newton_speedup
    );

    // --- 3) single-worker sweep vs segmented sweep across 4 workers ---
    // Dual regime (n >> p): preparation is shared through the cache, the
    // per-point dual solves are the serial chain being split. Kernel
    // parallelism is pinned to 1 thread per worker so the comparison
    // isolates the segmentation win.
    let (pn, pp, grid_n) = if full { (1500usize, 48usize, 24) } else { (150, 10, 6) };
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("path-{pn}x{pp}"),
        n: pn,
        p: pp,
        support: (pp / 5).max(3),
        seed: 7272,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig {
        grid: grid_n,
        path: PathSettings { num_lambda: 60, ..Default::default() },
        ..Default::default()
    });
    let grid = runner.derive_grid(&data);
    if grid.len() < 4 {
        println!("grid too small ({} points), skipping segment comparison", grid.len());
        return (panel_speedup, newton_speedup, f64::NAN);
    }
    let points = runner.grid_points(&grid);
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let yv = Arc::new(data.y.clone());
    let serve = |workers: usize, segment_min: usize| -> (f64, Vec<Vec<f64>>) {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers, queue_capacity: 32 },
            sven: crate::solvers::sven::SvenConfig {
                parallelism: Parallelism::Fixed(1),
                ..Default::default()
            },
            path_segment_min: segment_min,
            ..Default::default()
        });
        // warm the prep cache so both sides time the sweep, not the build
        let rx = service
            .submit_point(
                1,
                x.clone(),
                yv.clone(),
                points[0].t,
                points[0].lambda2,
                BackendChoice::Rust,
            )
            .expect("accepting");
        rx.recv().unwrap().result.expect("warm prep");
        let timer = Timer::start();
        let mut betas = Vec::new();
        for _ in 0..reps {
            let rx = service
                .submit_path(1, x.clone(), yv.clone(), points.clone(), BackendChoice::Rust)
                .expect("accepting");
            let sols = rx.recv().unwrap().result.expect("path").expect_path();
            betas = sols.into_iter().map(|s| s.beta).collect();
        }
        let secs = timer.elapsed() / reps as f64;
        service.shutdown();
        (secs, betas)
    };
    let (t_single, betas_single) = serve(1, usize::MAX);
    let seg_min = (points.len() / 4).max(2);
    let (t_seg, betas_seg) = serve(4, seg_min);
    // Segmentation must not move a single bit (the tests pin this too;
    // the bench re-checks it at the bench shape).
    assert_eq!(betas_single.len(), betas_seg.len());
    for (i, (a, b)) in betas_single.iter().zip(&betas_seg).enumerate() {
        for j in 0..a.len() {
            assert_eq!(
                a[j].to_bits(),
                b[j].to_bits(),
                "segmented sweep diverged at point {i} j={j}"
            );
        }
    }
    let seg_speedup = t_single / t_seg;
    println!(
        "path sweep {} points ({pn}x{pp}, dual): 1 worker {:.2}ms | 4 workers segmented \
         {:.2}ms ({:.2}x, bit-identical)",
        points.len(),
        t_single * 1e3,
        t_seg * 1e3,
        seg_speedup
    );
    (panel_speedup, newton_speedup, seg_speedup)
}

// ---------------------------------------------------------------------------
// Batched-solve / CV micro-bench (blocked CG panels, CvPath jobs)
// ---------------------------------------------------------------------------

/// Batched-solve micro-bench, two comparisons:
///
/// 1. width-1 CG (one solo `cg_solve_with` per right-hand side) vs the
///    blocked `cg_solve_multi_with` at panel widths 2/4/8 on a
///    memory-bound two-matvec ridge Hessian — the panel streams X once
///    per iteration for every system (per-column bit-identity asserted
///    even in smoke mode);
/// 2. k standalone fold `Path` jobs vs one `JobKind::CvPath` job over
///    the same folds and grid through the service (fold paths asserted
///    bit-identical even in smoke mode).
///
/// `full` runs the acceptance shapes; otherwise tiny CI-smoke shapes.
/// Returns (blocked-CG speedup at width 4, k-standalone/CvPath
/// wall-clock ratio).
pub fn cv_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::coordinator::{cv, BackendChoice, PoolConfig, Service, ServiceConfig};
    use crate::linalg::{cg_solve_multi_with, cg_solve_with, CgOptions, CgScratch, Mat, MultiVec};
    use crate::testing::prop::{RidgeFamily, RidgeOp};
    use std::sync::Arc;

    let reps = if full { 7 } else { 2 };
    println!("=== cv micro: blocked CG panels / CvPath jobs ===");
    let mut rng = crate::rng::Rng::seed_from(8181);

    // --- 1) width-1 CG vs blocked CG on the shared ridge-Hessian
    // test double (same operator the blocked-CG proptests pin) ---
    let (cn, cd) = if full { (4096usize, 512usize) } else { (240, 48) };
    let x = Mat::from_fn(cn, cd, |_, _| rng.normal());
    let opts = CgOptions { tol: 1e-10, max_iter: 40 };
    let mut speedup_w4 = f64::NAN;
    for w in [2usize, 4, 8] {
        let shifts: Vec<f64> = (0..w).map(|i| 1.0 + i as f64).collect();
        let b = MultiVec::from_fn(cd, w, |_, _| rng.normal());
        let mut scratch = CgScratch::new();
        let t_solo = measure(1, reps, || {
            for j in 0..w {
                let op = RidgeOp::new(&x, shifts[j]);
                let mut sol = vec![0.0; cd];
                cg_solve_with(&op, b.col(j), &mut sol, &opts, &mut scratch);
            }
        })
        .summary
        .median();
        let opts_vec = vec![opts.clone(); w];
        let t_multi = measure(1, reps, || {
            let fam = RidgeFamily::new(&x, shifts.clone());
            let mut sol = MultiVec::zeros(cd, w);
            cg_solve_multi_with(&fam, &b, &mut sol, &opts_vec, &mut scratch);
        })
        .summary
        .median();
        let sp = t_solo / t_multi;
        if w == 4 {
            speedup_w4 = sp;
        }
        println!(
            "blocked-cg X {cn}x{cd} width {w}: {w} solo solves {:.2}ms | blocked {:.2}ms \
             ({:.2}x)",
            t_solo * 1e3,
            t_multi * 1e3,
            sp
        );
        // Column-wise bit-identity, re-checked at the bench shape (the
        // proptests pin it at small shapes).
        let fam = RidgeFamily::new(&x, shifts.clone());
        let mut sol_m = MultiVec::zeros(cd, w);
        cg_solve_multi_with(&fam, &b, &mut sol_m, &opts_vec, &mut scratch);
        for j in 0..w {
            let op = RidgeOp::new(&x, shifts[j]);
            let mut sol_s = vec![0.0; cd];
            cg_solve_with(&op, b.col(j), &mut sol_s, &opts, &mut scratch);
            for i in 0..cd {
                assert_eq!(
                    sol_s[i].to_bits(),
                    sol_m.col(j)[i].to_bits(),
                    "blocked CG diverged from solo at w={w} col {j} i={i}"
                );
            }
        }
    }

    // --- 2) k standalone fold path jobs vs one CvPath job ---
    let (pn, pp, grid_n, folds) =
        if full { (1200usize, 32usize, 16, 4usize) } else { (120, 8, 5, 3) };
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("cv-{pn}x{pp}"),
        n: pn,
        p: pp,
        support: (pp / 4).max(3),
        seed: 8282,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig {
        grid: grid_n,
        path: PathSettings { num_lambda: 50, ..Default::default() },
        ..Default::default()
    });
    let grid = runner.derive_grid(&data);
    let mut points = runner.grid_points(&grid);
    points.retain(|gp| gp.t > 0.0);
    if points.len() < 2 {
        println!("grid too small ({} points), skipping CvPath comparison", points.len());
        return (speedup_w4, f64::NAN);
    }
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let y = Arc::new(data.y.clone());
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 4, queue_capacity: 64 },
        path_segment_min: 4,
        ..Default::default()
    });

    // k standalone jobs: fold problems built caller-side, one path job
    // each (this is what CV looked like before CvPath existed).
    let timer = Timer::start();
    let mut rxs = Vec::with_capacity(folds);
    for f in 0..folds {
        let (xf, yf) = cv::fold_problem(&x, &y, folds, f);
        let rx = service
            .submit_path(100 + f as u64, xf, yf, points.clone(), BackendChoice::Rust)
            .expect("service accepting jobs");
        rxs.push(rx);
    }
    let alone: Vec<Vec<crate::solvers::elastic_net::EnSolution>> = rxs
        .into_iter()
        .map(|rx| rx.recv().unwrap().result.expect("fold path").expect_path())
        .collect();
    let t_alone = timer.elapsed();

    // One CvPath job over the same folds and grid.
    let timer = Timer::start();
    let rx = service
        .submit_cv_path(200, x.clone(), y.clone(), folds, points.clone(), BackendChoice::Rust)
        .expect("service accepting jobs");
    let cvres = rx.recv().unwrap().result.expect("cv path").expect_cv_path();
    let t_cv = timer.elapsed();

    // The CV job must reproduce the standalone fold paths bit-for-bit
    // (asserted even in smoke mode).
    assert_eq!(cvres.fold_paths.len(), alone.len());
    for (f, (a, b)) in alone.iter().zip(&cvres.fold_paths).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (sa, sb)) in a.iter().zip(b).enumerate() {
            for j in 0..sa.beta.len() {
                assert_eq!(
                    sa.beta[j].to_bits(),
                    sb.beta[j].to_bits(),
                    "cv fold {f} point {i} j={j} diverged from standalone"
                );
            }
        }
    }
    let cv_speedup = t_alone / t_cv;
    println!(
        "cv {folds}-fold over {} points ({pn}x{pp}): {folds} standalone jobs {:.1}ms | \
         one CvPath job {:.1}ms ({:.2}x, bit-identical; best λ index {} of {})",
        points.len(),
        t_alone * 1e3,
        t_cv * 1e3,
        cv_speedup,
        cvres.best_index,
        cvres.cv_errors.len()
    );
    let m = service.metrics();
    println!(
        "cv metrics: cv_folds={} prep_builds={} batched_cg_rhs_total={} \
         batch_panel_rebuilds={}",
        m.cv_folds(),
        m.prep_builds(),
        m.batched_cg_rhs_total(),
        m.batch_panel_rebuilds()
    );
    service.shutdown();
    (speedup_w4, cv_speedup)
}

// ---------------------------------------------------------------------------
// Mixed-precision micro-bench (f32 panels + iterative refinement)
// ---------------------------------------------------------------------------

/// Mixed-precision micro-bench, two comparisons:
///
/// 1. the f64 GEMV pair (`X·v` then `Xᵀ·u` — the primal CG Hessian's
///    memory traffic) against the same products streamed from an f32
///    shadow ([`MatF32`](crate::linalg::MatF32)): bandwidth-bound, so
///    halving the streamed bytes targets ≥ 1.5× on the full shape;
/// 2. a primal-regime elastic-net solve under `Precision::F64` vs
///    `Precision::MixedF32`, asserting (even in smoke mode) that the
///    refined β agrees with the all-f64 β to solver tolerance and that
///    the mixed run actually took refinement passes.
///
/// `full` runs the acceptance shape; otherwise tiny CI-smoke shapes.
/// Returns (f32-over-f64 panel speedup, max |β_mixed − β_f64|).
pub fn precision_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::linalg::{Mat, MatF32, Precision};

    let reps = if full { 9 } else { 2 };
    println!("=== precision micro: f32 panels + f64 iterative refinement ===");
    let mut rng = crate::rng::Rng::seed_from(3232);

    // --- 1) f64 vs f32 GEMV pair on a bandwidth-bound shape ---
    let (m, p) = if full { (8192usize, 2048usize) } else { (512, 96) };
    let x = Mat::from_fn(m, p, |_, _| rng.normal());
    let x32 = MatF32::from_mat(&x);
    let v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let u: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let vf: Vec<f32> = v.iter().map(|&a| a as f32).collect();
    let uf: Vec<f32> = u.iter().map(|&a| a as f32).collect();
    let mut yo = vec![0.0; m];
    let mut to = vec![0.0; p];
    let t_f64 = measure(1, reps, || {
        x.matvec_into(&v, &mut yo);
        x.matvec_t_into(&u, &mut to);
    })
    .summary
    .median();
    let t_f32 = measure(1, reps, || {
        x32.matvec_into(&vf, &mut yo);
        x32.matvec_t_into(&uf, &mut to);
    })
    .summary
    .median();
    let panel_speedup = t_f64 / t_f32;
    let bytes = 2.0 * (m * p * 8) as f64;
    println!(
        "gemv pair {m}x{p}: f64 {:.3}ms ({:.1} GB/s) | f32 shadow {:.3}ms ({:.2}x; \
         target >= 1.5x on the bandwidth-bound full shape)",
        t_f64 * 1e3,
        bytes / t_f64 / 1e9,
        t_f32 * 1e3,
        panel_speedup
    );

    // --- 2) full solve: F64 vs MixedF32, refined-β agreement ---
    let (sn, sp2) = if full { (96usize, 1536usize) } else { (24, 64) };
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("prec-{sn}x{sp2}"),
        n: sn,
        p: sp2,
        support: (sp2 / 24).max(4),
        seed: 3333,
        ..Default::default()
    });
    let grid = grid_for(&data, 4);
    let Some(pt) = grid.last() else {
        println!("empty grid, skipping solve comparison");
        return (panel_speedup, f64::NAN);
    };
    let prob = EnProblem::new(data.x.clone(), data.y.clone(), pt.t, pt.lambda2.max(1e-6));
    let solve_at = |prec: Precision| {
        let sven = Sven::with_config(
            RustBackend::default(),
            crate::solvers::sven::SvenConfig { precision: prec, ..Default::default() },
        );
        let t = measure(1, reps.min(5), || sven.solve(&prob).unwrap()).summary.median();
        (t, sven.solve(&prob).unwrap())
    };
    let (t64, sol64) = solve_at(Precision::F64);
    let (t32, sol32) = solve_at(Precision::MixedF32);
    let dev = sol64
        .beta
        .iter()
        .zip(&sol32.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    // Refined agreement is a correctness bar, asserted even in smoke.
    assert!(dev < 5e-5, "mixed-f32 beta deviates from f64 by {dev:.3e}");
    assert!(sol32.refine_passes > 0, "mixed solve must take refinement passes");
    assert_eq!(sol64.refine_passes, 0, "f64 solve must not refine");
    println!(
        "en solve {sn}x{sp2} (primal): f64 {:.2}ms | mixed-f32 {:.2}ms ({:.2}x), \
         {} refine passes, max |dbeta| {dev:.2e}",
        t64 * 1e3,
        t32 * 1e3,
        t64 / t32,
        sol32.refine_passes
    );
    (panel_speedup, dev)
}

// ---------------------------------------------------------------------------
// Whole-screen serving micro-bench (MultiResponse jobs)
// ---------------------------------------------------------------------------

/// Whole-screen micro-bench: R standalone `Path` jobs vs one
/// `JobKind::MultiResponse` job over the same design and grid.
///
/// The screen shares one preparation and fuses every (response × grid
/// point) Newton direction into common SV panels, so the honest unit is
/// responses per second. Per-response bit-identity against the
/// standalone jobs (β bits *and* iteration counts) is asserted even in
/// smoke mode, as is a fused group width > 1 — the batch layer must
/// actually batch. The full run additionally writes `BENCH_PR8.json`
/// at the repo root (the perf-trajectory record).
///
/// `full` runs R = 8 and 64 at the acceptance shape; smoke runs R = 8
/// tiny. Returns (responses/sec speedup at the largest R, widest fused
/// Newton-direction group seen).
pub fn screen_micro(full: bool) -> (f64, f64) {
    use crate::coordinator::{BackendChoice, PoolConfig, Service, ServiceConfig};
    use crate::solvers::sven::SvmMode;
    use std::sync::Arc;

    println!("=== screen micro: standalone Path jobs vs one MultiResponse job ===");
    // Primal regime (2p > n): the response-batched panel layer is the
    // machinery under test, and it only engages in primal mode.
    let (n, p, grid_n) = if full { (256usize, 640usize, 12) } else { (40, 48, 4) };
    let rs: &[usize] = if full { &[8, 64] } else { &[8] };
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("screen-{n}x{p}"),
        n,
        p,
        support: (p / 16).max(4),
        seed: 9393,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig {
        grid: grid_n,
        path: PathSettings { num_lambda: 40, ..Default::default() },
        ..Default::default()
    });
    let derived = runner.derive_grid(&data);
    let mut points = runner.grid_points(&derived);
    points.retain(|gp| gp.t > 0.0);
    if points.len() < 2 {
        println!("grid too small ({} points), skipping screen comparison", points.len());
        return (f64::NAN, f64::NAN);
    }
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));

    let mut last_speedup = f64::NAN;
    let mut widest = 0usize;
    let mut json_rows: Vec<String> = Vec::new();
    for &r in rs {
        // Distinct responses as deterministic scalings of the base
        // signal — the shape of a screen of related phenotypes.
        let responses: Vec<Arc<Vec<f64>>> = (0..r)
            .map(|i| {
                let f = 1.0 + 0.5 * i as f64 / r as f64;
                Arc::new(data.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
            })
            .collect();
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 4, queue_capacity: 256 },
            ..Default::default()
        });
        // Warm the prep cache so both sides time sweeps, not the build.
        let rx = service
            .submit_point(
                1,
                x.clone(),
                responses[0].clone(),
                points[0].t,
                points[0].lambda2,
                BackendChoice::Rust,
            )
            .expect("accepting");
        rx.recv().unwrap().result.expect("warm prep");

        // R standalone path jobs: the screen without the batch layer.
        let timer = Timer::start();
        let rxs: Vec<_> = responses
            .iter()
            .map(|y| {
                service
                    .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
                    .expect("accepting")
            })
            .collect();
        let alone: Vec<Vec<crate::solvers::elastic_net::EnSolution>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().result.expect("solo path").expect_path())
            .collect();
        let t_alone = timer.elapsed();

        // One MultiResponse job over the same responses and grid.
        let timer = Timer::start();
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                responses.clone(),
                points.clone(),
                BackendChoice::Rust,
            )
            .expect("accepting");
        let multi = rx.recv().unwrap().result.expect("screen").expect_multi_response();
        let t_multi = timer.elapsed();

        // Per-response bit-identity with the standalone jobs, asserted
        // even in smoke mode: same β bits, same iteration counts.
        assert_eq!(multi.paths.len(), alone.len());
        for (ri, (a, b)) in alone.iter().zip(&multi.paths).enumerate() {
            assert_eq!(a.len(), b.len(), "response {ri} path length");
            for (i, (sa, sb)) in a.iter().zip(b).enumerate() {
                assert_eq!(
                    sa.iterations, sb.iterations,
                    "response {ri} point {i} iteration count diverged"
                );
                for j in 0..sa.beta.len() {
                    assert_eq!(
                        sa.beta[j].to_bits(),
                        sb.beta[j].to_bits(),
                        "screen diverged from standalone at response {ri} point {i} j={j}"
                    );
                }
            }
        }
        let m = service.metrics();
        // One preparation build for the whole comparison — the warm-up
        // built it, every job after (solo and screen) shared it.
        assert_eq!(m.prep_builds(), 1, "screen must reuse one preparation");
        assert_eq!(m.responses_total(), r as u64);
        let rps_alone = r as f64 / t_alone;
        let rps_multi = r as f64 / t_multi;
        let speedup = rps_multi / rps_alone;
        last_speedup = speedup;
        service.shutdown();

        // Fused-width histogram straight from the batch layer (the
        // service meters counts, not the histogram).
        let sven = Sven::new(RustBackend::default());
        let prep = sven.prepare_shared(&x, &responses[0]).expect("prepare");
        assert_eq!(prep.mode(), SvmMode::Primal, "bench shape must be primal");
        let live: Vec<usize> = (0..r).collect();
        let mut scratch = SvmScratch::new();
        let out = crate::coordinator::path::sweep_multi_prepared(
            &sven,
            prep.as_ref(),
            &mut scratch,
            &x,
            &responses,
            &live,
            &points,
            None,
            None,
            None,
        )
        .expect("multi sweep");
        widest = widest.max(out.stats.max_fused_width);
        // The fused panel must actually batch across responses.
        assert!(
            out.stats.max_fused_width > 1,
            "fused batch width stayed at 1 — responses never shared a panel"
        );
        println!(
            "screen R={r} over {} points ({n}x{p}, primal): {r} standalone jobs {:.1}ms \
             ({:.1} resp/s) | one MultiResponse job {:.1}ms ({:.1} resp/s, {:.2}x, \
             bit-identical)",
            points.len(),
            t_alone * 1e3,
            rps_alone,
            t_multi * 1e3,
            rps_multi,
            speedup
        );
        println!(
            "screen R={r} fused widths: max {} | hist(log2 buckets 1,2,4,...,128+) {:?} | \
             panel_builds={} batched_rhs={}",
            out.stats.max_fused_width,
            out.stats.width_hist,
            out.stats.panel_builds,
            out.stats.batched_rhs
        );
        json_rows.push(format!(
            "    {{\"responses\": {r}, \"grid_points\": {}, \"n\": {n}, \"p\": {p}, \
             \"standalone_seconds\": {:.6}, \"multi_seconds\": {:.6}, \
             \"standalone_responses_per_sec\": {:.3}, \"multi_responses_per_sec\": {:.3}, \
             \"speedup\": {:.4}, \"max_fused_width\": {}, \"width_hist\": {:?}}}",
            points.len(),
            t_alone,
            t_multi,
            rps_alone,
            rps_multi,
            speedup,
            out.stats.max_fused_width,
            out.stats.width_hist
        ));
    }
    if full {
        let json = format!(
            "{{\n  \"bench\": \"screen_micro\",\n  \"unit\": \"responses_per_second\",\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            json_rows.join(",\n")
        );
        // The trajectory record lives at the repo root, one level above
        // the crate manifest.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|d| d.join("BENCH_PR8.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_PR8.json"));
        match std::fs::write(&path, &json) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
        }
    }
    (last_speedup, widest as f64)
}

// ---------------------------------------------------------------------------
// Robustness micro-bench (admission sheds / deadline control / faults)
// ---------------------------------------------------------------------------

/// Robustness micro-bench for the fault-isolation layer, three
/// measurements:
///
/// 1. **Shed latency** — over-budget submissions must be rejected by
///    admission control without building any state (no id, no channel,
///    no preparation, no queue slot), so the unit is nanoseconds per
///    shed;
/// 2. **Deadline-control overhead** — a generous deadline arms the
///    grid-point boundary checks on a `Path` sweep; the controlled
///    sweep must stay bit-identical to the uncontrolled one (batch
///    composition never moves a bit), and the ratio prices the chunked
///    batching + clock polls;
/// 3. **Latency under faults** — p50/p99 round-trip latency of point
///    jobs through a service with an injected fault schedule (a failed
///    prep build, a solve panic, a pickup panic, two delays — all
///    retried) vs a clean service, with every faulted job still
///    succeeding bit-identically to the clean run.
///
/// 4. **Checkpoint economics** — on a dual-regime sweep (checkpoints
///    after every point): the publish cost of running with an armed
///    checkpoint slot vs without (target: < 2% of sweep time), and the
///    latency of a mid-sweep-killed-then-resumed retry vs the clean
///    sweep (a resume re-solves only the suffix; a scratch retry would
///    pay the prefix again). Both routes stay bit-identical.
///
/// All assertions run even in smoke mode. The full run writes
/// `BENCH_PR9.json` and `BENCH_PR10.json` at the repo root (the
/// robustness-trajectory records). Returns (deadline-control overhead
/// ratio, faulted-vs-clean p50 latency ratio).
pub fn robustness_micro(full: bool) -> (f64, f64) {
    use super::harness::measure;
    use crate::coordinator::{
        BackendChoice, FaultPlan, JobError, JobKind, PoolConfig, RetryPolicy, Service,
        ServiceConfig, SubmitOptions,
    };
    use std::sync::Arc;
    use std::time::Duration;

    println!("=== robustness micro: sheds / deadline control / faulted latency ===");
    let (n, p, grid_n) = if full { (200usize, 480usize, 16) } else { (40, 48, 6) };
    let data = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("robust-{n}x{p}"),
        n,
        p,
        support: (p / 16).max(4),
        seed: 4242,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig {
        grid: grid_n,
        path: PathSettings { num_lambda: 40, ..Default::default() },
        ..Default::default()
    });
    let derived = runner.derive_grid(&data);
    let mut points = runner.grid_points(&derived);
    points.retain(|gp| gp.t > 0.0);
    if points.len() < 2 {
        println!("grid too small ({} points), skipping robustness bench", points.len());
        return (f64::NAN, f64::NAN);
    }
    let x = Arc::new(crate::linalg::Design::from(data.x.clone()));
    let y = Arc::new(data.y.clone());

    // --- 1. shed latency: cost > budget is rejected before any state ---
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 1, queue_capacity: 8 },
        max_queue_depth: Some(1),
        ..Default::default()
    });
    let sheds = if full { 10_000usize } else { 200 };
    let timer = Timer::start();
    for _ in 0..sheds {
        let res =
            service.submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust);
        assert!(
            matches!(res, Err(JobError::Overloaded { .. })),
            "a path of {} solve-units must shed against a budget of 1",
            points.len()
        );
    }
    let shed_ns = timer.elapsed() * 1e9 / sheds as f64;
    let m = service.metrics();
    assert_eq!(m.jobs_shed(), sheds as u64);
    assert_eq!(m.submitted(), 0, "a shed submission must never count as submitted");
    assert_eq!(m.prep_builds(), 0, "a shed submission must build nothing");
    service.shutdown();
    println!("shed latency: {shed_ns:.0} ns/shed over {sheds} over-budget submissions");

    // --- 2. deadline-control overhead on a path sweep (bit-identical) ---
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 1, queue_capacity: 8 },
        ..Default::default()
    });
    let far = SubmitOptions::with_deadline(Duration::from_secs(3600));
    let reps = if full { 8 } else { 2 };
    // Warm the prep cache so both measurements time the sweep itself.
    let rx = service
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().expect("outcome").result.expect("path ok").expect_path();
    let t_clean = measure(1, reps, || {
        let rx = service
            .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
            .expect("accepted");
        rx.recv().expect("outcome").result.expect("path ok")
    })
    .summary
    .median();
    let rx = service
        .submit_path_with(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust, far)
        .expect("accepted");
    let controlled = rx.recv().expect("outcome").result.expect("path ok").expect_path();
    let t_ctl = measure(1, reps, || {
        let rx = service
            .submit_path_with(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust, far)
            .expect("accepted");
        rx.recv().expect("outcome").result.expect("path ok")
    })
    .summary
    .median();
    assert_eq!(clean.len(), controlled.len());
    for (i, (a, b)) in clean.iter().zip(&controlled).enumerate() {
        assert_eq!(a.iterations, b.iterations, "point {i}: iteration counts must match");
        for j in 0..a.beta.len() {
            assert_eq!(
                a.beta[j].to_bits(),
                b.beta[j].to_bits(),
                "point {i}: a deadline-armed sweep must stay bit-identical (j={j})"
            );
        }
    }
    let overhead = t_ctl / t_clean.max(1e-12);
    service.shutdown();
    println!(
        "deadline control: clean path {:.2}ms vs armed {:.2}ms ({overhead:.3}x, bit-identical)",
        t_clean * 1e3,
        t_ctl * 1e3
    );

    // --- 3. p50/p99 point-job latency under an injected fault schedule ---
    // One worker + sequential round trips keep the service-wide fault
    // ordinals on a deterministic schedule: prep build #0 fails (one
    // retry rebuilds it), solve #3 and pickup #6 panic (one retry each),
    // solves #5 and #9 stall 2 ms.
    let jobs = if full { 48usize } else { 12 };
    let plan = FaultPlan {
        prep_build_errors: vec![0],
        segment_panics: vec![6],
        solve_panics: vec![3],
        solve_delays: vec![(5, Duration::from_millis(2)), (9, Duration::from_millis(2))],
        ..Default::default()
    };
    let run = |plan: Option<FaultPlan>| {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            fault_plan: plan,
            ..Default::default()
        });
        let opts = SubmitOptions { retry: RetryPolicy::retries(3), ..Default::default() };
        let mut lat = Vec::with_capacity(jobs);
        let mut betas = Vec::with_capacity(jobs);
        for i in 0..jobs {
            let gp = points[i % points.len()];
            let t = Timer::start();
            let rx = service
                .submit_with(
                    1,
                    x.clone(),
                    y.clone(),
                    JobKind::Point { t: gp.t, lambda2: gp.lambda2 },
                    BackendChoice::Rust,
                    opts,
                )
                .expect("accepted");
            let sol = rx
                .recv()
                .expect("outcome")
                .result
                .expect("a faulted-but-retried job must still succeed")
                .expect_point();
            lat.push(t.elapsed());
            betas.push(sol.beta);
        }
        let m = service.metrics();
        let (retried, panics) = (m.jobs_retried(), m.worker_panics());
        service.shutdown();
        (lat, betas, retried, panics)
    };
    let (clean_lat, clean_betas, r0, _) = run(None);
    assert_eq!(r0, 0, "the clean service must not retry anything");
    let (fault_lat, fault_betas, retried, panics) = run(Some(plan));
    assert!(retried >= 3, "the schedule injects three retried faults, saw {retried}");
    assert!(panics >= 2, "the solve and pickup panics must be caught, saw {panics}");
    for (i, (a, b)) in clean_betas.iter().zip(&fault_betas).enumerate() {
        for j in 0..a.len() {
            assert_eq!(
                a[j].to_bits(),
                b[j].to_bits(),
                "job {i}: faulted-but-retried jobs must match the clean run (j={j})"
            );
        }
    }
    let pct = |lat: &[f64], q: f64| {
        let mut s = lat.to_vec();
        s.sort_by(f64::total_cmp);
        s[((s.len() - 1) as f64 * q) as usize]
    };
    let (c50, c99) = (pct(&clean_lat, 0.5), pct(&clean_lat, 0.99));
    let (f50, f99) = (pct(&fault_lat, 0.5), pct(&fault_lat, 0.99));
    let fault_ratio = f50 / c50.max(1e-12);
    println!(
        "faulted latency over {jobs} point jobs: clean p50 {:.2}ms p99 {:.2}ms | injected \
         p50 {:.2}ms p99 {:.2}ms ({retried} retries, {panics} caught panics, bit-identical)",
        c50 * 1e3,
        c99 * 1e3,
        f50 * 1e3,
        f99 * 1e3
    );

    // --- 4. checkpoint publish cost + resumed-vs-scratch retry latency ---
    // A dual-regime sweep checkpoints after every grid point, so this
    // section prices the per-point publish (a solution clone into the
    // shared slot) and the payoff: a retry that resumes mid-grid instead
    // of re-solving the prefix.
    let (nd, pd) = if full { (480usize, 60usize) } else { (120, 30) };
    let ddual = crate::data::synth_regression(&crate::data::SynthSpec {
        name: format!("robust-dual-{nd}x{pd}"),
        n: nd,
        p: pd,
        support: (pd / 5).max(4),
        seed: 4243,
        ..Default::default()
    });
    let dual_derived = runner.derive_grid(&ddual);
    let mut dual_points = runner.grid_points(&dual_derived);
    dual_points.retain(|gp| gp.t > 0.0);
    assert!(
        dual_points.len() >= 2,
        "dual grid collapsed to {} points; the checkpoint section needs a mid-grid kill",
        dual_points.len()
    );
    let xd = Arc::new(crate::linalg::Design::from(ddual.x.clone()));
    let yd = Arc::new(ddual.y.clone());
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 1, queue_capacity: 8 },
        ..Default::default()
    });
    // Warm the prep cache; measurements below time sweeps only.
    let rx = service
        .submit_path(2, xd.clone(), yd.clone(), dual_points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let dual_clean = rx.recv().expect("outcome").result.expect("path ok").expect_path();
    let with_retry = SubmitOptions { retry: RetryPolicy::retries(2), ..Default::default() };
    let t_plain = measure(1, reps, || {
        let rx = service
            .submit_path(2, xd.clone(), yd.clone(), dual_points.clone(), BackendChoice::Rust)
            .expect("accepted");
        rx.recv().expect("outcome").result.expect("path ok")
    })
    .summary
    .median();
    // `retries(2)` arms the checkpoint slot; with no fault injected the
    // only extra work is the per-point publish.
    let rx = service
        .submit_path_with(
            2,
            xd.clone(),
            yd.clone(),
            dual_points.clone(),
            BackendChoice::Rust,
            with_retry,
        )
        .expect("accepted");
    let ckpt_path = rx.recv().expect("outcome").result.expect("path ok").expect_path();
    for (i, (a, b)) in dual_clean.iter().zip(&ckpt_path).enumerate() {
        for j in 0..a.beta.len() {
            assert_eq!(
                a.beta[j].to_bits(),
                b.beta[j].to_bits(),
                "point {i}: an armed checkpoint slot must not move a bit (j={j})"
            );
        }
    }
    let t_ckpt = measure(1, reps, || {
        let rx = service
            .submit_path_with(
                2,
                xd.clone(),
                yd.clone(),
                dual_points.clone(),
                BackendChoice::Rust,
                with_retry,
            )
            .expect("accepted");
        rx.recv().expect("outcome").result.expect("path ok")
    })
    .summary
    .median();
    service.shutdown();
    let publish_cost = t_ckpt / t_plain.max(1e-12) - 1.0;
    assert!(
        publish_cost < 0.5,
        "checkpoint publishing cost {publish_cost:.3} of sweep time (target < 0.02)"
    );
    if full {
        assert!(
            publish_cost < 0.10,
            "full-size checkpoint publishing must stay well under the 2% target, \
             measured {publish_cost:.4}"
        );
    }
    println!(
        "checkpoint publish: plain {:.2}ms vs armed {:.2}ms ({:.2}% of sweep time, \
         target < 2%)",
        t_plain * 1e3,
        t_ckpt * 1e3,
        publish_cost * 100.0
    );
    // Resumed retry: a solve panic mid-grid kills the first attempt; the
    // retry resumes from the checkpointed prefix. Each repetition needs a
    // fresh service (fault ordinals are service-wide); a warm-up point
    // job builds the prep and consumes ordinal 0, so the kill lands at
    // grid index `mid` of the measured path job.
    let mid = dual_points.len() / 2;
    let resume_reps = if full { 5usize } else { 2 };
    let mut resumed_lat = Vec::with_capacity(resume_reps);
    for _ in 0..resume_reps {
        let svc = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 1, queue_capacity: 8 },
            fault_plan: Some(FaultPlan {
                solve_panics: vec![1 + mid as u64],
                ..Default::default()
            }),
            ..Default::default()
        });
        let gp = dual_points[0];
        let rx = svc
            .submit_point(2, xd.clone(), yd.clone(), gp.t, gp.lambda2, BackendChoice::Rust)
            .expect("accepted");
        rx.recv().expect("outcome").result.expect("warm-up point ok");
        let timer = Timer::start();
        let rx = svc
            .submit_path_with(
                2,
                xd.clone(),
                yd.clone(),
                dual_points.clone(),
                BackendChoice::Rust,
                with_retry,
            )
            .expect("accepted");
        let resumed = rx.recv().expect("outcome").result.expect("path ok").expect_path();
        resumed_lat.push(timer.elapsed());
        let m = svc.metrics();
        assert_eq!(m.resumed_from_checkpoint(), 1, "the retry must resume mid-grid");
        assert_eq!(
            m.checkpoints_published(),
            (dual_points.len() - mid) as u64,
            "the resumed prefix must not be re-published"
        );
        for (i, (a, b)) in dual_clean.iter().zip(&resumed).enumerate() {
            for j in 0..a.beta.len() {
                assert_eq!(
                    a.beta[j].to_bits(),
                    b.beta[j].to_bits(),
                    "point {i}: a resumed sweep must match the clean run (j={j})"
                );
            }
        }
        svc.shutdown();
    }
    resumed_lat.sort_by(f64::total_cmp);
    let t_resumed = resumed_lat[resumed_lat.len() / 2];
    let resumed_ratio = t_resumed / t_plain.max(1e-12);
    // A from-scratch retry killed at `mid` pays the prefix twice; the
    // resume only pays it once, so the estimated saving is the prefix
    // fraction of one sweep.
    let scratch_estimate = t_plain * (1.0 + mid as f64 / dual_points.len() as f64);
    println!(
        "resumed retry: clean sweep {:.2}ms, killed-at-{mid}-then-resumed {:.2}ms \
         ({resumed_ratio:.2}x; from-scratch retry estimate {:.2}ms)",
        t_plain * 1e3,
        t_resumed * 1e3,
        scratch_estimate * 1e3
    );

    if full {
        let json = format!(
            "{{\n  \"bench\": \"robustness_micro\",\n  \"rows\": [\n    {{\"shed_ns\": \
             {shed_ns:.0}, \"clean_path_seconds\": {t_clean:.6}, \"deadline_path_seconds\": \
             {t_ctl:.6}, \"deadline_overhead\": {overhead:.4}, \"jobs\": {jobs}, \
             \"clean_p50_seconds\": {c50:.6}, \"clean_p99_seconds\": {c99:.6}, \
             \"faulted_p50_seconds\": {f50:.6}, \"faulted_p99_seconds\": {f99:.6}, \
             \"retries\": {retried}, \"caught_panics\": {panics}}}\n  ]\n}}\n"
        );
        // The trajectory record lives at the repo root, one level above
        // the crate manifest.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|d| d.join("BENCH_PR9.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_PR9.json"));
        match std::fs::write(&path, &json) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
        }
        let json = format!(
            "{{\n  \"bench\": \"checkpoint_micro\",\n  \"rows\": [\n    {{\"grid_points\": \
             {}, \"kill_ordinal\": {mid}, \"plain_path_seconds\": {t_plain:.6}, \
             \"checkpointed_path_seconds\": {t_ckpt:.6}, \"publish_overhead\": \
             {publish_cost:.4}, \"resumed_retry_seconds\": {t_resumed:.6}, \
             \"resumed_over_clean\": {resumed_ratio:.4}, \"scratch_retry_estimate_seconds\": \
             {scratch_estimate:.6}}}\n  ]\n}}\n",
            dual_points.len()
        );
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .map(|d| d.join("BENCH_PR10.json"))
            .unwrap_or_else(|| std::path::PathBuf::from("BENCH_PR10.json"));
        match std::fs::write(&path, &json) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => eprintln!("[bench] could not write {}: {e}", path.display()),
        }
    }
    (overhead, fault_ratio)
}

// ---------------------------------------------------------------------------
// Figure 1
// ---------------------------------------------------------------------------

/// Regenerate Figure 1. Returns the max deviation between solvers.
pub fn figure1(seed: u64) -> f64 {
    println!("Figure 1 — regularization path, prostate-like data (n=97, p=8)");
    println!("paper claim: glmnet and SVEN paths match exactly for all t\n");
    let data = crate::data::prostate_like(seed);
    let grid = grid_for(&data, 40);
    let sven_cpu = Sven::new(RustBackend::default());
    let runner = PathRunner::new(PathRunnerConfig::default());
    let cpu_results = runner.run(&data, &sven_cpu, &grid).expect("cpu path");
    let xla_results = xla_sven().map(|s| runner.run(&data, &s, &grid).expect("xla path"));

    // β_j(t) table: the textual form of the Fig. 1 line plot.
    print!("{:>9} {:>5}", "t", "nnz");
    for j in 0..data.p() {
        print!(" {:>9}", format!("beta_{j}"));
    }
    println!(" {:>11} {:>11}", "dev_cpu", "dev_xla");
    for (i, r) in cpu_results.iter().enumerate() {
        print!("{:>9.4} {:>5}", r.t, r.nnz);
        for b in &r.beta {
            print!(" {:>9.4}", b);
        }
        let dev_xla = xla_results
            .as_ref()
            .map(|xr| xr[i].max_dev)
            .unwrap_or(f64::NAN);
        println!(" {:>11.2e} {:>11.2e}", r.max_dev, dev_xla);
    }
    let dev_cpu = crate::coordinator::path::max_deviation(&cpu_results);
    let dev_xla = xla_results
        .as_ref()
        .map(|r| crate::coordinator::path::max_deviation(r))
        .unwrap_or(f64::NAN);
    println!("\nmax |beta_sven − beta_glmnet| over the whole path:");
    println!("  SVEN (CPU): {dev_cpu:.3e}");
    println!("  SVEN (XLA): {dev_xla:.3e}");
    dev_cpu.max(if dev_xla.is_nan() { 0.0 } else { dev_xla })
}

// ---------------------------------------------------------------------------
// Figures 2 and 3 (shared sweep)
// ---------------------------------------------------------------------------

/// Which baselines run in a sweep (Lasso-only solvers skip κ < 1 points
/// exactly like the paper runs them with λ₂ = 0).
const BASELINES: &[&str] = &["glmnet", "shotgun", "l1_ls", "sven_cpu"];

/// Run the timing sweep for one dataset; returns table rows.
pub fn sweep_dataset(data: &Dataset, grid: &[PathPoint], rows: &mut Vec<BenchRow>) {
    let n = data.n();
    // --- SVEN (XLA): prepared once, warm-started sweep (the system under
    // test; its per-point time is the x-axis of the figure) ---
    let xla = xla_sven();
    let mut xla_times = vec![f64::NAN; grid.len()];
    let mut xla_devs = vec![f64::NAN; grid.len()];
    if let Some(sven) = &xla {
        let prep = sven.prepare(&data.x, &data.y).expect("xla prepare");
        let mut scratch = SvmScratch::new();
        let mut warm: Option<SvmWarm> = None;
        for (i, pt) in grid.iter().enumerate() {
            let prob = EnProblem::new(
                data.x.clone(),
                data.y.clone(),
                pt.t,
                pt.lambda2.max(1e-6),
            );
            let timer = Timer::start();
            let sol = sven
                .solve_prepared(prep.as_ref(), &mut scratch, &prob, warm.as_ref(), None)
                .expect("xla solve");
            xla_times[i] = timer.elapsed();
            xla_devs[i] = pt
                .beta
                .iter()
                .zip(&sol.beta)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            warm = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(pt.t)) });
        }
    }

    // --- baselines, cold per setting (paper's per-setting timing) ---
    for alg in BASELINES {
        // SVEN CPU gets prepared-reuse too (it is "our" method on CPU).
        let sven_cpu = Sven::new(RustBackend::default());
        let cpu_prep = match *alg {
            "sven_cpu" => Some(sven_cpu.prepare(&data.x, &data.y).expect("prep")),
            _ => None,
        };
        let mut scratch = SvmScratch::new();
        for (i, pt) in grid.iter().enumerate() {
            let timer = Timer::start();
            let (beta, ok): (Vec<f64>, bool) = match *alg {
                "glmnet" => {
                    let r = glmnet::solve_penalized(
                        &data.x,
                        &data.y,
                        pt.lambda,
                        &GlmnetConfig { kappa: pt.kappa, ..Default::default() },
                        None,
                    );
                    (r.beta, true)
                }
                "shotgun" => {
                    let r = solve_shotgun(
                        &data.x,
                        &data.y,
                        pt.lambda,
                        &ShotgunConfig { kappa: pt.kappa, ..Default::default() },
                        None,
                    );
                    (r.beta, true)
                }
                "l1_ls" => {
                    // Lasso-only (paper: λ₂ = 0 for the pure Lasso solvers)
                    let r = solve_l1ls(
                        &data.x,
                        &data.y,
                        pt.lambda * pt.kappa,
                        &L1LsConfig::default(),
                    );
                    (r.beta, true)
                }
                "sven_cpu" => {
                    let prob = EnProblem::new(
                        data.x.clone(),
                        data.y.clone(),
                        pt.t,
                        pt.lambda2.max(1e-6),
                    );
                    let sol = sven_cpu
                        .solve_prepared(
                            cpu_prep.as_ref().unwrap().as_ref(),
                            &mut scratch,
                            &prob,
                            None,
                            None,
                        )
                        .expect("sven cpu");
                    (sol.beta, true)
                }
                _ => unreachable!(),
            };
            let seconds = timer.elapsed();
            if !ok {
                continue;
            }
            // correctness: deviation vs the glmnet reference path point —
            // for l1_ls (pure Lasso) the reference has λ₂ > 0, so we only
            // use dev as a sanity indicator there.
            let max_dev = pt
                .beta
                .iter()
                .zip(&beta)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            let sven_s = xla_times[i];
            rows.push(BenchRow {
                dataset: data.name.clone(),
                setting: i,
                t: pt.t,
                lambda2: pt.lambda2,
                algorithm: alg.to_string(),
                seconds,
                sven_xla_seconds: sven_s,
                ratio: seconds / sven_s,
                max_dev,
            });
        }
    }
    // SVEN XLA rows (ratio 1.0 by construction; dev from its own run)
    for (i, pt) in grid.iter().enumerate() {
        rows.push(BenchRow {
            dataset: data.name.clone(),
            setting: i,
            t: pt.t,
            lambda2: pt.lambda2,
            algorithm: "sven_xla".to_string(),
            seconds: xla_times[i],
            sven_xla_seconds: xla_times[i],
            ratio: 1.0,
            max_dev: xla_devs[i],
        });
    }
    let _ = n;
}

/// Figure 2: the eight p ≫ n profiles.
pub fn figure2(seed: u64) -> Vec<BenchRow> {
    let factor = super::size_factor();
    let grid_n = super::grid_size();
    println!(
        "Figure 2 — p >> n training-time comparison (scale={}, grid={})",
        factor, grid_n
    );
    let mut rows = Vec::new();
    for profile in profiles::p_gg_n() {
        let data = scaled_dataset(profile, factor, seed);
        eprintln!("[figure2] {} (n={}, p={})", data.name, data.n(), data.p());
        let grid = grid_for(&data, grid_n);
        if grid.is_empty() {
            eprintln!("[figure2] {}: empty grid, skipping", data.name);
            continue;
        }
        sweep_dataset(&data, &grid, &mut rows);
    }
    print_table("Figure 2 (p >> n)", &rows);
    rows
}

/// Figure 3: the four n ≫ p profiles.
pub fn figure3(seed: u64) -> Vec<BenchRow> {
    let factor = super::size_factor();
    let grid_n = super::grid_size();
    println!(
        "Figure 3 — n >> p training-time comparison (scale={}, grid={})",
        factor, grid_n
    );
    let mut rows = Vec::new();
    for profile in profiles::n_gg_p() {
        let data = scaled_dataset(profile, factor, seed);
        eprintln!("[figure3] {} (n={}, p={})", data.name, data.n(), data.p());
        let grid = grid_for(&data, grid_n);
        if grid.is_empty() {
            eprintln!("[figure3] {}: empty grid, skipping", data.name);
            continue;
        }
        sweep_dataset(&data, &grid, &mut rows);
    }
    print_table("Figure 3 (n >> p)", &rows);
    rows
}

// ---------------------------------------------------------------------------
// Ablations
// ---------------------------------------------------------------------------

/// Ablation suite (see DESIGN.md §5): prints its own tables.
pub fn ablations(seed: u64) {
    ablation_mode_crossover(seed);
    ablation_warm_start(seed);
    ablation_gram_cache(seed);
    ablation_padding(seed);
    ablation_scale_sweep(seed);
}

/// Scale sweep: the paper's headline comparison is hardware-bound — CD
/// baselines win small problems (tiny active sets, cache-resident data),
/// the brute-force parallel SVM wins as the problem grows. This ablation
/// tracks glmnet time vs SVEN (XLA) time on a growing PEMS-like profile
/// so the crossover direction is visible even on CI-sized runs.
fn ablation_scale_sweep(seed: u64) {
    println!("\n=== Ablation: problem scale vs solver time (PEMS-like, p >> n) ===");
    let Some(xla) = xla_sven() else {
        println!("skipped (artifacts not built)");
        return;
    };
    println!(
        "{:>8} {:>8} {:>12} {:>12} {:>10}",
        "n", "p", "glmnet_s", "sven_xla_s", "ratio"
    );
    for (n, p) in [(32usize, 1000usize), (64, 2500), (128, 6000), (256, 12000)] {
        let d = crate::data::synth_regression(&crate::data::SynthSpec {
            name: format!("pems-{n}x{p}"),
            n,
            p,
            support: (p / 60).max(8),
            rho: 0.8,
            density: 1.0,
            snr: 4.0,
            seed: seed ^ (n * p) as u64,
        });
        let grid = grid_for(&d, 3);
        let Some(pt) = grid.last() else { continue };
        // glmnet cold at the same penalized setting
        let mg = super::harness::measure(1, 3, || {
            glmnet::solve_penalized(
                &d.x,
                &d.y,
                pt.lambda,
                &GlmnetConfig { kappa: pt.kappa, ..Default::default() },
                None,
            )
        });
        // SVEN (XLA) prepared (path-amortized staging, as in the figures)
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), pt.t, pt.lambda2.max(1e-6));
        let prep = xla.prepare(&d.x, &d.y).expect("prep");
        let mut scratch = SvmScratch::new();
        let mx = super::harness::measure(1, 3, || {
            xla.solve_prepared(prep.as_ref(), &mut scratch, &prob, None, None).unwrap()
        });
        println!(
            "{:>8} {:>8} {:>12.4} {:>12.4} {:>10.2}",
            n,
            p,
            mg.summary.median(),
            mx.summary.median(),
            mg.summary.median() / mx.summary.median()
        );
    }
    println!("expected shape: ratio rises with scale (the paper's GPU crossover)");
}

/// Primal vs dual crossover around 2p ≈ n.
fn ablation_mode_crossover(seed: u64) {
    use crate::solvers::sven::{SvenConfig, SvmMode};
    println!("\n=== Ablation: primal vs dual crossover (fixed p=48, varying n) ===");
    println!("{:>6} {:>6} {:>12} {:>12} {:>10}", "n", "2p", "primal_s", "dual_s", "winner");
    for n in [24usize, 48, 96, 192, 384, 768] {
        let d = crate::data::synth_regression(&crate::data::SynthSpec {
            n,
            p: 48,
            support: 8,
            seed: seed ^ n as u64,
            ..Default::default()
        });
        let grid = grid_for(&d, 4);
        let Some(pt) = grid.last() else { continue };
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), pt.t, pt.lambda2.max(1e-4));
        let time_mode = |mode: SvmMode| {
            let sven = Sven::with_config(
                RustBackend::default(),
                SvenConfig { mode, ..Default::default() },
            );
            let m = super::harness::measure(1, 3, || sven.solve(&prob).unwrap());
            m.summary.median()
        };
        let tp = time_mode(SvmMode::Primal);
        let td = time_mode(SvmMode::Dual);
        println!(
            "{:>6} {:>6} {:>12.6} {:>12.6} {:>10}",
            n,
            96,
            tp,
            td,
            if tp < td { "primal" } else { "dual" }
        );
    }
    println!("expected shape: primal wins while 2p > n, dual wins once n >> 2p");
}

/// Warm vs cold start along a path (dual regime — the warm state the
/// path runner carries is the dual free set, which the primal ignores).
fn ablation_warm_start(seed: u64) {
    println!("\n=== Ablation: warm vs cold start along the path (dual regime) ===");
    let d = crate::data::synth_regression(&crate::data::SynthSpec {
        n: 400,
        p: 50,
        support: 12,
        seed,
        ..Default::default()
    });
    let sven = Sven::new(RustBackend::default());
    let grid = grid_for(&d, 10);
    let run = |warm_start: bool| {
        let runner = PathRunner::new(PathRunnerConfig {
            grid: 10,
            warm_start,
            ..Default::default()
        });
        let timer = Timer::start();
        let res = runner.run(&d, &sven, &grid).unwrap();
        let iters: usize = res.iter().map(|r| r.iterations).sum();
        (timer.elapsed(), iters)
    };
    let (cold_s, cold_it) = run(false);
    let (warm_s, warm_it) = run(true);
    println!("cold: {cold_s:.4}s, {cold_it} total Newton iters");
    println!("warm: {warm_s:.4}s, {warm_it} total Newton iters");
}

/// Gram caching on/off for the dual regime (the Figure-3 mechanism).
fn ablation_gram_cache(seed: u64) {
    println!("\n=== Ablation: gram caching in the n >> p regime ===");
    let d = crate::data::synth_regression(&crate::data::SynthSpec {
        n: 4000,
        p: 60,
        support: 10,
        seed,
        ..Default::default()
    });
    let sven = Sven::new(RustBackend::default());
    let grid = grid_for(&d, 6);
    // cached: prepare once
    let timer = Timer::start();
    let prep = sven.prepare(&d.x, &d.y).unwrap();
    let mut scratch = SvmScratch::new();
    for pt in &grid {
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), pt.t, pt.lambda2.max(1e-4));
        sven.solve_prepared(prep.as_ref(), &mut scratch, &prob, None, None).unwrap();
    }
    let cached = timer.elapsed();
    // uncached: re-prepare per point (what a naive implementation does)
    let timer = Timer::start();
    for pt in &grid {
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), pt.t, pt.lambda2.max(1e-4));
        sven.solve(&prob).unwrap();
    }
    let uncached = timer.elapsed();
    println!(
        "6-point path: cached gram {cached:.4}s vs re-prepared {uncached:.4}s ({:.1}x)",
        uncached / cached
    );
}

/// Bucket padding overhead on the XLA backend.
fn ablation_padding(seed: u64) {
    println!("\n=== Ablation: shape-bucket padding overhead (XLA backend) ===");
    let Some(sven) = xla_sven() else {
        println!("skipped (artifacts not built)");
        return;
    };
    // (20, 40) pads into the (32, 64) bucket; (30, 62) nearly fills it.
    for (n, p) in [(20usize, 40usize), (30, 62)] {
        let d = crate::data::synth_regression(&crate::data::SynthSpec {
            n,
            p,
            support: 6,
            seed: seed ^ (n * p) as u64,
            ..Default::default()
        });
        let grid = grid_for(&d, 3);
        let Some(pt) = grid.last() else { continue };
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), pt.t, pt.lambda2.max(1e-4));
        let prep = sven.prepare(&d.x, &d.y).unwrap();
        let mut scratch = SvmScratch::new();
        let m = super::harness::measure(1, 5, || {
            sven.solve_prepared(prep.as_ref(), &mut scratch, &prob, None, None).unwrap()
        });
        let fill = (n * p) as f64 / (32.0 * 64.0);
        println!(
            "problem ({n:>3} x {p:>3}) fill {:>5.2} of bucket (32x64): median {:.6}s",
            fill,
            m.summary.median()
        );
    }
    println!("expected shape: near-constant time per bucket (padding is masked compute)");
}

/// Write rows to a CSV next to the bench output for plotting.
pub fn write_csv(path: &str, rows: &[BenchRow]) {
    use std::io::Write;
    let mut f = std::fs::File::create(path).expect("create csv");
    writeln!(f, "{}", BenchRow::csv_header()).unwrap();
    for r in rows {
        writeln!(f, "{}", r.csv()).unwrap();
    }
    eprintln!("[bench] wrote {path}");
}
