//! Timing primitives and table output for the figure benches.

use crate::util::{Summary, Timer};

/// One measured quantity.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub summary: Summary,
    pub reps: usize,
}

/// Measure a closure: `warmup` unrecorded runs, then `reps` timed runs.
pub fn measure<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.elapsed());
    }
    Measurement { summary: Summary::from(times), reps: reps.max(1) }
}

/// A row of a figure table: one (dataset, setting, algorithm) cell.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub dataset: String,
    pub setting: usize,
    pub t: f64,
    pub lambda2: f64,
    pub algorithm: String,
    pub seconds: f64,
    /// SVEN (XLA) seconds on the same setting — the figure's x-axis.
    pub sven_xla_seconds: f64,
    /// seconds / sven_xla_seconds (> 1 ⇒ above the diagonal: SVEN wins).
    pub ratio: f64,
    /// max |β − β_ref| against the glmnet reference (correctness check).
    pub max_dev: f64,
}

impl BenchRow {
    pub fn header() -> String {
        format!(
            "{:<14} {:>4} {:>10} {:>10} {:<10} {:>12} {:>12} {:>8} {:>10}",
            "dataset", "set", "t", "lambda2", "algorithm", "time_s", "sven_xla_s", "ratio",
            "max_dev"
        )
    }

    pub fn line(&self) -> String {
        format!(
            "{:<14} {:>4} {:>10.4} {:>10.4} {:<10} {:>12.6} {:>12.6} {:>8.2} {:>10.2e}",
            self.dataset,
            self.setting,
            self.t,
            self.lambda2,
            self.algorithm,
            self.seconds,
            self.sven_xla_seconds,
            self.ratio,
            self.max_dev
        )
    }

    /// CSV form (for EXPERIMENTS.md ingestion / plotting).
    pub fn csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{}",
            self.dataset,
            self.setting,
            self.t,
            self.lambda2,
            self.algorithm,
            self.seconds,
            self.sven_xla_seconds,
            self.ratio,
            self.max_dev
        )
    }

    pub fn csv_header() -> &'static str {
        "dataset,setting,t,lambda2,algorithm,seconds,sven_xla_seconds,ratio,max_dev"
    }
}

/// Print a full table plus per-algorithm summary (the "who wins by what
/// factor" digest that mirrors reading the scatter plot).
pub fn print_table(title: &str, rows: &[BenchRow]) {
    println!("\n=== {title} ===");
    println!("{}", BenchRow::header());
    for r in rows {
        println!("{}", r.line());
    }
    // digest: per algorithm, geometric-mean ratio and win fraction
    let mut algs: Vec<String> = rows.iter().map(|r| r.algorithm.clone()).collect();
    algs.sort();
    algs.dedup();
    println!("--- digest (vs SVEN (XLA)) ---");
    for alg in algs {
        let rs: Vec<&BenchRow> = rows.iter().filter(|r| r.algorithm == alg).collect();
        if rs.is_empty() {
            continue;
        }
        let geo = (rs.iter().map(|r| r.ratio.max(1e-12).ln()).sum::<f64>()
            / rs.len() as f64)
            .exp();
        let wins = rs.iter().filter(|r| r.ratio > 1.0).count();
        let max_dev = rs.iter().map(|r| r.max_dev).fold(0.0f64, f64::max);
        println!(
            "{:<10} geo-mean ratio {:>7.2}x   sven-xla faster on {:>3}/{:<3}   max_dev {:.2e}",
            alg,
            geo,
            wins,
            rs.len(),
            max_dev
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let m = measure(1, 5, || 1 + 1);
        assert_eq!(m.reps, 5);
        assert!(m.summary.min() >= 0.0);
    }

    #[test]
    fn row_formats() {
        let r = BenchRow {
            dataset: "GLI-85".into(),
            setting: 3,
            t: 1.5,
            lambda2: 0.2,
            algorithm: "glmnet".into(),
            seconds: 0.5,
            sven_xla_seconds: 0.1,
            ratio: 5.0,
            max_dev: 1e-7,
        };
        assert!(r.line().contains("glmnet"));
        assert_eq!(r.csv().split(',').count(), 9);
    }
}
