//! The XLA engine: compile cache + typed execution of the three artifact
//! programs. One engine per process; executables are compiled on first
//! use and shared across worker threads.

use super::artifact::{ArtifactKind, ArtifactMeta, Registry};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compiled-executable cache keyed by artifact name.
pub struct XlaEngine {
    client: PjRtClient,
    registry: Registry,
    compiled: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    /// Compile-cache statistics (hits, misses) for the metrics endpoint.
    stats: Mutex<(u64, u64)>,
}

impl XlaEngine {
    /// Create a CPU PJRT client and load the artifact registry.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        let registry = Registry::load(dir)?;
        Ok(XlaEngine {
            client,
            registry,
            compiled: Mutex::new(HashMap::new()),
            stats: Mutex::new((0, 0)),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// (hits, misses) of the compile cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        *self.stats.lock().unwrap()
    }

    /// Get (compile if needed) the executable for an artifact.
    pub fn executable(&self, meta: &ArtifactMeta) -> Result<Arc<PjRtLoadedExecutable>> {
        {
            let cache = self.compiled.lock().unwrap();
            if let Some(exe) = cache.get(&meta.name) {
                self.stats.lock().unwrap().0 += 1;
                return Ok(exe.clone());
            }
        }
        // Compile outside the lock: compilation takes seconds and other
        // workers may want other artifacts meanwhile.
        let proto = HloModuleProto::from_text_file(&meta.file)
            .map_err(|e| anyhow!("parsing {}: {e}", meta.file.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", meta.name))?,
        );
        let mut cache = self.compiled.lock().unwrap();
        self.stats.lock().unwrap().1 += 1;
        Ok(cache.entry(meta.name.clone()).or_insert(exe).clone())
    }

    /// Pre-compile every artifact (warmup; used by the coordinator at
    /// startup so the request path never pays compile latency).
    pub fn warmup(&self) -> Result<usize> {
        let metas: Vec<ArtifactMeta> = self.registry.artifacts.clone();
        for meta in &metas {
            self.executable(meta)?;
        }
        Ok(metas.len())
    }

    /// Stage a host f64 tensor on the device.
    pub fn stage(&self, data: &[f64], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("staging buffer {:?}: {e}", dims))
    }

    /// Stage a scalar.
    pub fn stage_scalar(&self, v: f64) -> Result<PjRtBuffer> {
        self.stage(&[v], &[])
    }

    /// Execute an artifact on staged buffers and return the tuple fields
    /// as literals.
    pub fn run(
        &self,
        meta: &ArtifactMeta,
        args: &[&PjRtBuffer],
    ) -> Result<Vec<Literal>> {
        let exe = self.executable(meta)?;
        let outs = exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e}", meta.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", meta.name))?;
        // Artifacts are lowered with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", meta.name))
    }

    // ---------------------------------------------------------------------
    // Typed wrappers for the three programs
    // ---------------------------------------------------------------------

    /// `gram(X, y) → (G0 (p_b×p_b), v (p_b), yy)` — padded outputs stay on
    /// the bucket shape so they can feed the matching dual artifact.
    pub fn run_gram(
        &self,
        meta: &ArtifactMeta,
        x_pad: &PjRtBuffer,
        y_pad: &PjRtBuffer,
    ) -> Result<(Literal, Literal, Literal)> {
        debug_assert_eq!(meta.kind, ArtifactKind::Gram);
        let mut parts = self.run(meta, &[x_pad, y_pad])?;
        if parts.len() != 3 {
            return Err(anyhow!("gram returned {} outputs", parts.len()));
        }
        let yy = parts.pop().unwrap();
        let v = parts.pop().unwrap();
        let g0 = parts.pop().unwrap();
        Ok((g0, v, yy))
    }

    /// `svm_primal(X, y, t, c, mask, w0) → (w (n_b), α (2p_b), iters)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_primal(
        &self,
        meta: &ArtifactMeta,
        x_pad: &PjRtBuffer,
        y_pad: &PjRtBuffer,
        t: f64,
        c: f64,
        mask: &PjRtBuffer,
        w0: &PjRtBuffer,
    ) -> Result<(Vec<f64>, Vec<f64>, usize)> {
        debug_assert_eq!(meta.kind, ArtifactKind::Primal);
        let t_buf = self.stage_scalar(t)?;
        let c_buf = self.stage_scalar(c)?;
        let parts =
            self.run(meta, &[x_pad, y_pad, &t_buf, &c_buf, mask, w0])?;
        if parts.len() != 3 {
            return Err(anyhow!("primal returned {} outputs", parts.len()));
        }
        let w = parts[0].to_vec::<f64>()?;
        let alpha = parts[1].to_vec::<f64>()?;
        let iters = parts[2].to_vec::<f64>()?[0] as usize;
        Ok((w, alpha, iters))
    }

    /// `svm_dual(G0, v, yy, t, c, mask, α0) → (α (2p_b), iters)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_dual(
        &self,
        meta: &ArtifactMeta,
        g0: &PjRtBuffer,
        v: &PjRtBuffer,
        yy: &PjRtBuffer,
        t: f64,
        c: f64,
        mask: &PjRtBuffer,
        alpha0: &PjRtBuffer,
    ) -> Result<(Vec<f64>, usize)> {
        debug_assert_eq!(meta.kind, ArtifactKind::Dual);
        let t_buf = self.stage_scalar(t)?;
        let c_buf = self.stage_scalar(c)?;
        let parts =
            self.run(meta, &[g0, v, yy, &t_buf, &c_buf, mask, alpha0])?;
        if parts.len() != 2 {
            return Err(anyhow!("dual returned {} outputs", parts.len()));
        }
        let alpha = parts[0].to_vec::<f64>()?;
        let iters = parts[1].to_vec::<f64>()?[0] as usize;
        Ok((alpha, iters))
    }

    /// Re-stage a literal as a device buffer (gram outputs → dual inputs).
    pub fn stage_literal(&self, lit: &Literal, dims: &[usize]) -> Result<PjRtBuffer> {
        let host = lit.to_vec::<f64>()?;
        self.stage(&host, dims)
    }
}

/// Pad a row-major (n × p) f64 matrix into bucket shape (n_b × p_b).
pub fn pad_matrix(
    data: &[f64],
    n: usize,
    p: usize,
    n_b: usize,
    p_b: usize,
) -> Vec<f64> {
    assert!(n_b >= n && p_b >= p);
    let mut out = vec![0.0; n_b * p_b];
    for r in 0..n {
        out[r * p_b..r * p_b + p].copy_from_slice(&data[r * p..(r + 1) * p]);
    }
    out
}

/// Pad a length-n vector to n_b.
pub fn pad_vec(data: &[f64], n_b: usize) -> Vec<f64> {
    let mut out = vec![0.0; n_b];
    out[..data.len()].copy_from_slice(data);
    out
}

/// Sample mask for a problem with p features padded to p_b: the 2p_b-long
/// SVEN mask with 1s at [0, p) and [p_b, p_b + p).
pub fn sample_mask(p: usize, p_b: usize) -> Vec<f64> {
    let mut mask = vec![0.0; 2 * p_b];
    for v in mask[..p].iter_mut() {
        *v = 1.0;
    }
    for v in mask[p_b..p_b + p].iter_mut() {
        *v = 1.0;
    }
    mask
}

/// Extract the snug 2p-long α from the padded 2p_b-long one.
pub fn unpad_alpha(alpha_pad: &[f64], p: usize, p_b: usize) -> Vec<f64> {
    assert_eq!(alpha_pad.len(), 2 * p_b);
    let mut out = Vec::with_capacity(2 * p);
    out.extend_from_slice(&alpha_pad[..p]);
    out.extend_from_slice(&alpha_pad[p_b..p_b + p]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_matrix_layout() {
        // [[1,2],[3,4]] → 3×4 bucket
        let padded = pad_matrix(&[1.0, 2.0, 3.0, 4.0], 2, 2, 3, 4);
        assert_eq!(
            padded,
            vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]
        );
    }

    #[test]
    fn sample_mask_layout() {
        assert_eq!(sample_mask(2, 3), vec![1.0, 1.0, 0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn unpad_alpha_roundtrip() {
        let padded = vec![1.0, 2.0, 0.0, 3.0, 4.0, 0.0]; // p=2, p_b=3
        assert_eq!(unpad_alpha(&padded, 2, 3), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn pad_vec_extends() {
        assert_eq!(pad_vec(&[1.0, 2.0], 4), vec![1.0, 2.0, 0.0, 0.0]);
    }
}
