//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The offline crate set has no serde_json; this recursive-descent parser
//! covers the JSON subset the manifest uses (objects, arrays, strings,
//! numbers, booleans, null) with full escape handling for strings.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(format!("expected '{s}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| JsonError {
                                        pos: self.pos,
                                        msg: "bad \\u escape".into(),
                                    })?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| {
                                JsonError { pos: self.pos, msg: "bad \\u escape".into() }
                            })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| {
                            JsonError { pos: start, msg: "invalid utf-8".into() }
                        })?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        match s.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("bad number '{s}'")),
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"format": 1, "artifacts": [
            {"name": "svm_primal_n128_p512", "kind": "primal", "n": 128, "p": 512},
            {"name": "svm_dual_p64", "kind": "dual", "p": 64}
        ], "fingerprint": "abc123"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_usize(), Some(1));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[0].get("kind").unwrap().as_str(), Some("primal"));
        assert_eq!(arts[1].get("p").unwrap().as_usize(), Some(64));
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#"{"s": "a\"b\\c\ndA"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn numbers() {
        let v = parse("[-1.5e3, 0, 42, 0.25]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[2].as_usize(), Some(42));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn nested_and_literals() {
        let v = parse(r#"{"a": [true, false, null, {"b": []}]}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0], Json::Bool(true));
        assert_eq!(a[2], Json::Null);
        assert!(a[3].get("b").unwrap().as_arr().unwrap().is_empty());
    }
}
