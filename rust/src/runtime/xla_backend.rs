//! [`XlaBackend`]: the SVEN SVM backend that executes the AOT artifacts —
//! "SVEN (XLA)", the paper's "SVEN (GPU)" under our hardware substitution
//! (DESIGN.md §3).
//!
//! Preparation stages the (padded) data set on the device once; in dual
//! mode it additionally runs the gram artifact and keeps `G₀, v, yy`
//! device-resident, so each of the 40 path points is a single executable
//! launch with two scalars and two small vectors as fresh inputs — the
//! structure that makes the paper's Figure-3 timings flat in t.

use super::engine::{pad_matrix, pad_vec, sample_mask, unpad_alpha, XlaEngine};
use crate::linalg::{Design, Mat};
use crate::solvers::svm::SolveCtl;
use crate::solvers::sven::{SvmBackend, SvmMode, SvmPrep, SvmScratch, SvmSolve, SvmWarm};
use anyhow::{anyhow, Result};
use std::sync::Arc;
use xla::PjRtBuffer;

/// SVEN backend over the PJRT engine. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct XlaBackend {
    engine: Arc<XlaEngine>,
}

impl XlaBackend {
    pub fn new(engine: Arc<XlaEngine>) -> Self {
        XlaBackend { engine }
    }

    /// Load from the default artifact directory.
    pub fn from_default_dir() -> Result<Self> {
        Ok(XlaBackend {
            engine: Arc::new(XlaEngine::load(&super::default_artifact_dir())?),
        })
    }

    pub fn engine(&self) -> &Arc<XlaEngine> {
        &self.engine
    }
}

impl SvmBackend for XlaBackend {
    fn name(&self) -> &str {
        "xla-pjrt"
    }

    fn prepare(
        &self,
        x: &Arc<Design>,
        y: &Arc<Vec<f64>>,
        mode: SvmMode,
    ) -> Result<Arc<dyn SvmPrep>> {
        let (n, p) = (x.rows(), x.cols());
        // The AOT artifacts consume padded dense buffers, so the device
        // boundary is where a sparse design finally densifies — one copy,
        // staged once per data set (the CPU backend never does this).
        let dense_holder;
        let x: &Mat = match x.as_dense() {
            Some(m) => m,
            None => {
                dense_holder = x.to_dense();
                &dense_holder
            }
        };
        match mode.resolve(n, p) {
            SvmMode::Primal => {
                let meta = self
                    .engine
                    .registry()
                    .primal_bucket(n, p)
                    .ok_or_else(|| {
                        anyhow!("no primal bucket covers n={n}, p={p} — extend aot.py PRIMAL_BUCKETS")
                    })?
                    .clone();
                let x_pad = pad_matrix(x.data(), n, p, meta.n, meta.p);
                let x_buf = self.engine.stage(&x_pad, &[meta.n, meta.p])?;
                let y_buf = self.engine.stage(&pad_vec(y, meta.n), &[meta.n])?;
                let mask_buf =
                    self.engine.stage(&sample_mask(p, meta.p), &[2 * meta.p])?;
                Ok(Arc::new(PreparedXlaPrimal {
                    engine: self.engine.clone(),
                    meta,
                    n,
                    p,
                    x_buf,
                    y_buf,
                    mask_buf,
                }))
            }
            SvmMode::Dual => {
                let gram_meta = self
                    .engine
                    .registry()
                    .gram_bucket(n, p)
                    .ok_or_else(|| {
                        anyhow!("no gram bucket covers n={n}, p={p} — extend aot.py GRAM_BUCKETS")
                    })?
                    .clone();
                let dual_meta = self
                    .engine
                    .registry()
                    .dual_bucket_exact(gram_meta.p)
                    .ok_or_else(|| {
                        anyhow!("no dual bucket at p={} for gram {}", gram_meta.p, gram_meta.name)
                    })?
                    .clone();
                // Run gram once; keep G0/v/yy device-resident.
                let x_pad = pad_matrix(x.data(), n, p, gram_meta.n, gram_meta.p);
                let x_buf = self.engine.stage(&x_pad, &[gram_meta.n, gram_meta.p])?;
                let y_buf =
                    self.engine.stage(&pad_vec(y, gram_meta.n), &[gram_meta.n])?;
                let (g0_lit, v_lit, yy_lit) =
                    self.engine.run_gram(&gram_meta, &x_buf, &y_buf)?;
                let pb = gram_meta.p;
                let g0_buf = self.engine.stage_literal(&g0_lit, &[pb, pb])?;
                let v_buf = self.engine.stage_literal(&v_lit, &[pb])?;
                let yy_buf = self.engine.stage_literal(&yy_lit, &[])?;
                let mask_buf = self.engine.stage(&sample_mask(p, pb), &[2 * pb])?;
                Ok(Arc::new(PreparedXlaDual {
                    engine: self.engine.clone(),
                    meta: dual_meta,
                    n,
                    p,
                    p_b: pb,
                    g0_buf,
                    v_buf,
                    yy_buf,
                    mask_buf,
                }))
            }
            SvmMode::Auto => unreachable!(),
        }
    }
}

/// Primal-mode prepared problem: padded X, y, mask staged on device.
struct PreparedXlaPrimal {
    engine: Arc<XlaEngine>,
    meta: crate::runtime::ArtifactMeta,
    n: usize,
    p: usize,
    x_buf: PjRtBuffer,
    y_buf: PjRtBuffer,
    mask_buf: PjRtBuffer,
}

impl SvmPrep for PreparedXlaPrimal {
    fn solve(
        &self,
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        _scratch: &mut SvmScratch,
        _ctl: Option<&SolveCtl>,
    ) -> Result<SvmSolve> {
        let w0_host = match warm.and_then(|w| w.w.as_ref()) {
            Some(w) => pad_vec(w, self.meta.n),
            None => vec![0.0; self.meta.n],
        };
        let w0 = self.engine.stage(&w0_host, &[self.meta.n])?;
        let (w_pad, alpha_pad, iters) = self.engine.run_primal(
            &self.meta,
            &self.x_buf,
            &self.y_buf,
            t,
            c,
            &self.mask_buf,
            &w0,
        )?;
        Ok(SvmSolve {
            alpha: unpad_alpha(&alpha_pad, self.p, self.meta.p),
            w: Some(w_pad[..self.n].to_vec()),
            iters,
            cg_iters: 0,
            gather_rebuilds: 0,
            refine_passes: 0,
            aborted: false,
            broken: None,
        })
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Primal
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.p)
    }
}

/// Dual-mode prepared problem: gram pieces staged on device.
struct PreparedXlaDual {
    engine: Arc<XlaEngine>,
    meta: crate::runtime::ArtifactMeta,
    n: usize,
    p: usize,
    p_b: usize,
    g0_buf: PjRtBuffer,
    v_buf: PjRtBuffer,
    yy_buf: PjRtBuffer,
    mask_buf: PjRtBuffer,
}

impl SvmPrep for PreparedXlaDual {
    fn solve(
        &self,
        t: f64,
        c: f64,
        warm: Option<&SvmWarm>,
        _scratch: &mut SvmScratch,
        _ctl: Option<&SolveCtl>,
    ) -> Result<SvmSolve> {
        let alpha0_host = match warm.and_then(|w| w.alpha.as_ref()) {
            Some(a) => {
                // re-pad the snug 2p warm start into bucket layout
                let mut padded = vec![0.0; 2 * self.p_b];
                padded[..self.p].copy_from_slice(&a[..self.p]);
                padded[self.p_b..self.p_b + self.p].copy_from_slice(&a[self.p..]);
                padded
            }
            None => vec![0.0; 2 * self.p_b],
        };
        let alpha0 = self.engine.stage(&alpha0_host, &[2 * self.p_b])?;
        let (alpha_pad, iters) = self.engine.run_dual(
            &self.meta,
            &self.g0_buf,
            &self.v_buf,
            &self.yy_buf,
            t,
            c,
            &self.mask_buf,
            &alpha0,
        )?;
        Ok(SvmSolve {
            alpha: unpad_alpha(&alpha_pad, self.p, self.p_b),
            w: None,
            iters,
            cg_iters: 0,
            gather_rebuilds: 0,
            refine_passes: 0,
            aborted: false,
            broken: None,
        })
    }

    fn mode(&self) -> SvmMode {
        SvmMode::Dual
    }

    fn dims(&self) -> (usize, usize) {
        (self.n, self.p)
    }
}
