//! Artifact registry: parse `manifest.json` and answer shape-bucket
//! queries ("smallest primal bucket covering (n, p)").

use super::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which L2 program an artifact encodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `svm_primal_program(X, y, t, c, mask, w0) → (w, α, iters)`.
    Primal,
    /// `svm_dual_program(G0, v, yy, t, c, mask, α0) → (α, iters)`.
    Dual,
    /// `gram_program(X, y) → (G0, v, yy)`.
    Gram,
}

impl ArtifactKind {
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "primal" => ArtifactKind::Primal,
            "dual" => ArtifactKind::Dual,
            "gram" => ArtifactKind::Gram,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

/// One artifact's metadata from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: PathBuf,
    /// Bucket dims: regression-problem n (absent for dual) and p.
    pub n: usize,
    pub p: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Registry {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let doc = json::parse(&text).context("parsing manifest.json")?;
        let fingerprint = doc
            .get("fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut artifacts = Vec::new();
        for item in doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?
        {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let kind = ArtifactKind::from_str(
                item.get("kind").and_then(Json::as_str).unwrap_or(""),
            )?;
            let file = dir.join(
                item.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?,
            );
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            let n = item.get("n").and_then(Json::as_usize).unwrap_or(0);
            let p = item
                .get("p")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact {name} missing p"))?;
            artifacts.push(ArtifactMeta { name, kind, file, n, p });
        }
        let reg = Registry { dir: dir.to_path_buf(), fingerprint, artifacts };
        reg.validate()?;
        Ok(reg)
    }

    /// Every gram bucket's p must have a matching dual bucket (the dual
    /// solve consumes the gram output at the same padded p).
    fn validate(&self) -> Result<()> {
        for g in self.of_kind(ArtifactKind::Gram) {
            if !self
                .of_kind(ArtifactKind::Dual)
                .iter()
                .any(|d| d.p == g.p)
            {
                bail!(
                    "gram bucket {} (p={}) has no matching dual bucket",
                    g.name,
                    g.p
                );
            }
        }
        Ok(())
    }

    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Smallest primal bucket with `n_b ≥ n` and `p_b ≥ p` (by padded
    /// area, the proxy for wasted compute).
    pub fn primal_bucket(&self, n: usize, p: usize) -> Option<&ArtifactMeta> {
        self.of_kind(ArtifactKind::Primal)
            .into_iter()
            .filter(|a| a.n >= n && a.p >= p)
            .min_by_key(|a| a.n * a.p)
    }

    /// Smallest gram bucket covering (n, p).
    pub fn gram_bucket(&self, n: usize, p: usize) -> Option<&ArtifactMeta> {
        self.of_kind(ArtifactKind::Gram)
            .into_iter()
            .filter(|a| a.n >= n && a.p >= p)
            .min_by_key(|a| a.n * a.p)
    }

    /// Dual bucket at exactly the given padded p.
    pub fn dual_bucket_exact(&self, p: usize) -> Option<&ArtifactMeta> {
        self.of_kind(ArtifactKind::Dual).into_iter().find(|a| a.p == p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_registry(dir: &Path) -> Registry {
        std::fs::create_dir_all(dir).unwrap();
        let arts = [
            ("svm_primal_n32_p64", "primal", 32usize, 64usize),
            ("svm_primal_n128_p512", "primal", 128, 512),
            ("svm_primal_n128_p2048", "primal", 128, 2048),
            ("svm_dual_p16", "dual", 0, 16),
            ("svm_dual_p64", "dual", 0, 64),
            ("gram_n256_p16", "gram", 256, 16),
            ("gram_n2048_p64", "gram", 2048, 64),
        ];
        let mut items = Vec::new();
        for (name, kind, n, p) in arts {
            let file = format!("{name}.hlo.txt");
            std::fs::File::create(dir.join(&file))
                .unwrap()
                .write_all(b"HloModule fake\n")
                .unwrap();
            let nfield = if kind == "dual" {
                String::new()
            } else {
                format!("\"n\": {n}, ")
            };
            items.push(format!(
                "{{\"name\": \"{name}\", \"kind\": \"{kind}\", \"file\": \"{file}\", {nfield}\"p\": {p}}}"
            ));
        }
        let manifest = format!(
            "{{\"format\": 1, \"fingerprint\": \"t\", \"artifacts\": [{}]}}",
            items.join(",")
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        Registry::load(dir).unwrap()
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sven_reg_test_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn loads_and_indexes() {
        let dir = tmpdir("load");
        let reg = fake_registry(&dir);
        assert_eq!(reg.artifacts.len(), 7);
        assert_eq!(reg.of_kind(ArtifactKind::Primal).len(), 3);
    }

    #[test]
    fn primal_bucket_selection_smallest_cover() {
        let dir = tmpdir("bucket");
        let reg = fake_registry(&dir);
        let b = reg.primal_bucket(100, 400).unwrap();
        assert_eq!((b.n, b.p), (128, 512));
        let b2 = reg.primal_bucket(10, 10).unwrap();
        assert_eq!((b2.n, b2.p), (32, 64));
        assert!(reg.primal_bucket(4096, 4096).is_none());
    }

    #[test]
    fn gram_and_dual_pair() {
        let dir = tmpdir("pair");
        let reg = fake_registry(&dir);
        let g = reg.gram_bucket(1000, 50).unwrap();
        assert_eq!((g.n, g.p), (2048, 64));
        assert!(reg.dual_bucket_exact(g.p).is_some());
    }

    #[test]
    fn missing_file_rejected() {
        let dir = tmpdir("missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [{"name": "x", "kind": "dual", "file": "nope.hlo.txt", "p": 4}]}"#,
        )
        .unwrap();
        assert!(Registry::load(&dir).is_err());
    }
}
