//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`), compile
//! them once per shape bucket on the PJRT CPU client, and expose them as a
//! [`crate::solvers::sven::SvmBackend`] — the "SVEN (XLA)" backend that
//! stands in for the paper's GPU offload.
//!
//! Flow (mirrors /opt/xla-example/load_hlo):
//! ```text
//! manifest.json → HloModuleProto::from_text_file → XlaComputation
//!   → PjRtClient::cpu().compile (cached) → execute_b(staged buffers)
//! ```
//!
//! Problems are padded to the smallest covering shape bucket; the
//! validity mask makes padding exact (python/tests/test_padding.py and
//! rust/tests/padding.rs prove this on both sides of the boundary).

pub mod artifact;
pub mod engine;
pub mod json;
pub mod xla_backend;

pub use artifact::{ArtifactKind, ArtifactMeta, Registry};
pub use engine::XlaEngine;
pub use xla_backend::XlaBackend;

/// Default artifact directory, overridable with SVEN_ARTIFACTS.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var("SVEN_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
