//! Standardization: center y, center + scale features.
//!
//! The paper (following Zou & Hastie 2005) assumes the response is
//! centered and features normalized. glmnet's convention scales each
//! column to `‖x_j‖²/n = 1`; we match that so λ values transfer.

use crate::linalg::{vecops, Mat};

/// Recorded transformation so solutions can be mapped back to the
/// original units.
#[derive(Clone, Debug)]
pub struct Standardization {
    pub x_mean: Vec<f64>,
    pub x_scale: Vec<f64>,
    pub y_mean: f64,
}

impl Standardization {
    /// Map standardized-space coefficients back to original units,
    /// returning (β_orig, intercept).
    pub fn unstandardize(&self, beta: &[f64]) -> (Vec<f64>, f64) {
        let beta_orig: Vec<f64> = beta
            .iter()
            .zip(&self.x_scale)
            .map(|(b, s)| if *s > 0.0 { b / s } else { 0.0 })
            .collect();
        let intercept = self.y_mean
            - beta_orig
                .iter()
                .zip(&self.x_mean)
                .map(|(b, m)| b * m)
                .sum::<f64>();
        (beta_orig, intercept)
    }
}

/// Center y; center each column of X and scale it to `‖x_j‖² = n`.
/// Constant (zero-variance) columns are left at zero (the paper removes
/// all-zero features; we neutralize them the same way).
pub fn standardize(x: &Mat, y: &[f64]) -> (Mat, Vec<f64>, Standardization) {
    standardize_opts(x, y, true)
}

/// [`standardize`] with optional feature centering. Sparse designs
/// (Dorothea/E2006-style) skip centering so zeros stay zero — the same
/// convention glmnet applies to sparse inputs.
pub fn standardize_opts(x: &Mat, y: &[f64], center: bool) -> (Mat, Vec<f64>, Standardization) {
    let (n, p) = (x.rows(), x.cols());
    assert_eq!(y.len(), n);
    let y_mean = vecops::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let mut x_mean = vec![0.0; p];
    if center {
        for r in 0..n {
            vecops::axpy(1.0, x.row(r), &mut x_mean);
        }
        vecops::scale(1.0 / n as f64, &mut x_mean);
    }

    // column scales: ‖x_j − mean‖ / √n
    let mut ssq = vec![0.0; p];
    for r in 0..n {
        let row = x.row(r);
        for j in 0..p {
            let d = row[j] - x_mean[j];
            ssq[j] += d * d;
        }
    }
    let x_scale: Vec<f64> = ssq.iter().map(|s| (s / n as f64).sqrt()).collect();

    let mut xs = Mat::zeros(n, p);
    for r in 0..n {
        let src = x.row(r);
        let dst = xs.row_mut(r);
        for j in 0..p {
            dst[j] = if x_scale[j] > 1e-12 { (src[j] - x_mean[j]) / x_scale[j] } else { 0.0 };
        }
    }
    (xs, yc, Standardization { x_mean, x_scale, y_mean })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn centers_and_scales() {
        let mut rng = Rng::seed_from(61);
        let x = Mat::from_fn(30, 5, |_, _| rng.normal_ms(3.0, 2.0));
        let y: Vec<f64> = (0..30).map(|_| rng.normal_ms(-1.0, 4.0)).collect();
        let (xs, yc, _) = standardize(&x, &y);
        assert!(vecops::mean(&yc).abs() < 1e-10);
        for j in 0..5 {
            let col = xs.col(j);
            assert!(vecops::mean(&col).abs() < 1e-10, "col {j} mean");
            assert!((vecops::norm2_sq(&col) - 30.0).abs() < 1e-8, "col {j} scale");
        }
    }

    #[test]
    fn constant_column_neutralized() {
        let x = Mat::from_fn(10, 2, |r, c| if c == 0 { 7.0 } else { r as f64 });
        let y = vec![1.0; 10];
        let (xs, _, _) = standardize(&x, &y);
        for r in 0..10 {
            assert_eq!(xs.get(r, 0), 0.0);
        }
    }

    #[test]
    fn unstandardize_roundtrip_prediction() {
        let mut rng = Rng::seed_from(62);
        let x = Mat::from_fn(25, 3, |_, _| rng.normal_ms(5.0, 3.0));
        let y: Vec<f64> = (0..25).map(|_| rng.normal_ms(2.0, 1.0)).collect();
        let (xs, yc, std) = standardize(&x, &y);
        let beta_std = vec![0.4, -0.2, 0.1];
        let (beta_orig, intercept) = std.unstandardize(&beta_std);
        // predictions must agree: xs·β_std + ȳ == x·β_orig + intercept
        let pred_std = xs.matvec(&beta_std);
        let pred_orig = x.matvec(&beta_orig);
        for i in 0..25 {
            let a = pred_std[i] + std.y_mean;
            let b = pred_orig[i] + intercept;
            assert!((a - b).abs() < 1e-8, "i={i}: {a} vs {b}");
        }
        let _ = yc;
    }
}
