//! Standardization: center y, center + scale features.
//!
//! The paper (following Zou & Hastie 2005) assumes the response is
//! centered and features normalized. glmnet's convention scales each
//! column to `‖x_j‖²/n = 1`; we match that so λ values transfer.

use crate::linalg::{vecops, Design, Mat};

/// Recorded transformation so solutions can be mapped back to the
/// original units.
#[derive(Clone, Debug)]
pub struct Standardization {
    pub x_mean: Vec<f64>,
    pub x_scale: Vec<f64>,
    pub y_mean: f64,
}

impl Standardization {
    /// Map standardized-space coefficients back to original units,
    /// returning (β_orig, intercept).
    pub fn unstandardize(&self, beta: &[f64]) -> (Vec<f64>, f64) {
        let beta_orig: Vec<f64> = beta
            .iter()
            .zip(&self.x_scale)
            .map(|(b, s)| if *s > 0.0 { b / s } else { 0.0 })
            .collect();
        let intercept = self.y_mean
            - beta_orig
                .iter()
                .zip(&self.x_mean)
                .map(|(b, m)| b * m)
                .sum::<f64>();
        (beta_orig, intercept)
    }
}

/// Center y; center each column of X and scale it to `‖x_j‖² = n`.
/// Constant (zero-variance) columns are left at zero (the paper removes
/// all-zero features; we neutralize them the same way).
pub fn standardize(x: &Mat, y: &[f64]) -> (Mat, Vec<f64>, Standardization) {
    standardize_opts(x, y, true)
}

/// [`standardize`] with optional feature centering. Sparse designs
/// (Dorothea/E2006-style) skip centering so zeros stay zero — the same
/// convention glmnet applies to sparse inputs.
pub fn standardize_opts(x: &Mat, y: &[f64], center: bool) -> (Mat, Vec<f64>, Standardization) {
    let (n, p) = (x.rows(), x.cols());
    assert_eq!(y.len(), n);
    let y_mean = vecops::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let mut x_mean = vec![0.0; p];
    if center {
        for r in 0..n {
            vecops::axpy(1.0, x.row(r), &mut x_mean);
        }
        vecops::scale(1.0 / n as f64, &mut x_mean);
    }

    // column scales: ‖x_j − mean‖ / √n
    let mut ssq = vec![0.0; p];
    for r in 0..n {
        let row = x.row(r);
        for j in 0..p {
            let d = row[j] - x_mean[j];
            ssq[j] += d * d;
        }
    }
    let x_scale: Vec<f64> = ssq.iter().map(|s| (s / n as f64).sqrt()).collect();

    let mut xs = Mat::zeros(n, p);
    for r in 0..n {
        let src = x.row(r);
        let dst = xs.row_mut(r);
        for j in 0..p {
            dst[j] = if x_scale[j] > 1e-12 { (src[j] - x_mean[j]) / x_scale[j] } else { 0.0 };
        }
    }
    (xs, yc, Standardization { x_mean, x_scale, y_mean })
}

/// Standardize a [`Design`] of either storage kind.
///
/// Dense designs get the full center + scale treatment of
/// [`standardize`]. Sparse designs stay sparse: the column means are
/// *tracked* in the returned [`Standardization`] (computed as `Xᵀ·1/n`,
/// no fill-in) and the stored values are scaled by the centered standard
/// deviation `√(‖x_j‖²/n − x̄_j²)` built from [`Design::col_norms_sq`],
/// but the means are never subtracted from the matrix, so zeros stay
/// zero — the convention glmnet applies to sparse inputs (solvers fold
/// the tracked means in implicitly). Zero-variance columns are
/// neutralized to all-zero in both kinds. Note the sparse variance uses
/// the one-pass `E[x²] − x̄²` form (clamped at 0), which can cancel for
/// near-constant columns; the `1e-12` scale floor catches the exact
/// cases.
pub fn standardize_design(x: &Design, y: &[f64]) -> (Design, Vec<f64>, Standardization) {
    match x {
        Design::Dense(m) => {
            let (xs, yc, st) = standardize(m, y);
            (Design::Dense(xs), yc, st)
        }
        Design::Sparse { csr, .. } => {
            let n = csr.rows();
            assert_eq!(y.len(), n);
            let y_mean = vecops::mean(y);
            let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
            let inv_n = 1.0 / n as f64;
            let mut x_mean = csr.matvec_t(&vec![1.0; n]);
            vecops::scale(inv_n, &mut x_mean);
            let x_scale: Vec<f64> = csr
                .col_norms_sq()
                .iter()
                .zip(&x_mean)
                .map(|(s, m)| (s * inv_n - m * m).max(0.0).sqrt())
                .collect();
            let factor: Vec<f64> =
                x_scale.iter().map(|&s| if s > 1e-12 { 1.0 / s } else { 0.0 }).collect();
            let mut scaled = csr.clone();
            scaled.scale_cols(&factor);
            (Design::from(scaled), yc, Standardization { x_mean, x_scale, y_mean })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Csr;
    use crate::rng::Rng;

    #[test]
    fn centers_and_scales() {
        let mut rng = Rng::seed_from(61);
        let x = Mat::from_fn(30, 5, |_, _| rng.normal_ms(3.0, 2.0));
        let y: Vec<f64> = (0..30).map(|_| rng.normal_ms(-1.0, 4.0)).collect();
        let (xs, yc, _) = standardize(&x, &y);
        assert!(vecops::mean(&yc).abs() < 1e-10);
        for j in 0..5 {
            let col = xs.col(j);
            assert!(vecops::mean(&col).abs() < 1e-10, "col {j} mean");
            assert!((vecops::norm2_sq(&col) - 30.0).abs() < 1e-8, "col {j} scale");
        }
    }

    #[test]
    fn constant_column_neutralized() {
        let x = Mat::from_fn(10, 2, |r, c| if c == 0 { 7.0 } else { r as f64 });
        let y = vec![1.0; 10];
        let (xs, _, _) = standardize(&x, &y);
        for r in 0..10 {
            assert_eq!(xs.get(r, 0), 0.0);
        }
    }

    #[test]
    fn unstandardize_roundtrip_prediction() {
        let mut rng = Rng::seed_from(62);
        let x = Mat::from_fn(25, 3, |_, _| rng.normal_ms(5.0, 3.0));
        let y: Vec<f64> = (0..25).map(|_| rng.normal_ms(2.0, 1.0)).collect();
        let (xs, yc, std) = standardize(&x, &y);
        let beta_std = vec![0.4, -0.2, 0.1];
        let (beta_orig, intercept) = std.unstandardize(&beta_std);
        // predictions must agree: xs·β_std + ȳ == x·β_orig + intercept
        let pred_std = xs.matvec(&beta_std);
        let pred_orig = x.matvec(&beta_orig);
        for i in 0..25 {
            let a = pred_std[i] + std.y_mean;
            let b = pred_orig[i] + intercept;
            assert!((a - b).abs() < 1e-8, "i={i}: {a} vs {b}");
        }
        let _ = yc;
    }

    #[test]
    fn design_dense_delegates_to_standardize() {
        let mut rng = Rng::seed_from(64);
        let x = Mat::from_fn(20, 4, |_, _| rng.normal_ms(2.0, 3.0));
        let y: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let (xs, yc, st) = standardize(&x, &y);
        let (ds, dyc, dst) = standardize_design(&Design::from(x), &y);
        assert!(!ds.is_sparse());
        assert_eq!(ds.to_dense().data(), xs.data());
        assert_eq!(dyc, yc);
        assert_eq!(dst.x_mean, st.x_mean);
        assert_eq!(dst.x_scale, st.x_scale);
    }

    #[test]
    fn sparse_standardize_tracks_means_without_fill_in() {
        let mut rng = Rng::seed_from(65);
        let dense = Mat::from_fn(40, 6, |_, _| {
            if rng.bernoulli(0.35) {
                rng.normal_ms(1.5, 2.0)
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..40).map(|_| rng.normal_ms(0.5, 1.0)).collect();
        let csr = Csr::from_dense(&dense, 0.0);
        let nnz = csr.nnz();
        let (ds, yc, st) = standardize_design(&Design::from(csr), &y);
        assert!(ds.is_sparse());
        assert_eq!(ds.nnz(), nnz, "scaling must not change the sparsity structure");
        assert!(vecops::mean(&yc).abs() < 1e-10);
        // tracked moments agree with the dense centered standardizer
        let (_, _, dst) = standardize(&dense, &y);
        for j in 0..6 {
            assert!((st.x_mean[j] - dst.x_mean[j]).abs() < 1e-10, "mean {j}");
            assert!((st.x_scale[j] - dst.x_scale[j]).abs() < 1e-10, "scale {j}");
        }
        // entries are x/σ: zeros stay zero, nonzeros scaled in place
        let scaled = ds.to_dense();
        for r in 0..40 {
            for j in 0..6 {
                let expect =
                    if st.x_scale[j] > 1e-12 { dense.get(r, j) / st.x_scale[j] } else { 0.0 };
                assert!((scaled.get(r, j) - expect).abs() < 1e-12, "({r},{j})");
            }
        }
    }

    #[test]
    fn sparse_constant_column_neutralized() {
        // column 0 is the constant 5.0: zero centered variance, so its
        // stored values are zeroed instead of divided by a ~0 scale
        let dense = Mat::from_fn(8, 2, |r, c| if c == 0 { 5.0 } else { (r % 3) as f64 });
        let y = vec![2.0; 8];
        let (ds, _, st) = standardize_design(&Design::from(Csr::from_dense(&dense, 0.0)), &y);
        assert!(st.x_scale[0].abs() < 1e-9);
        let d = ds.to_dense();
        for r in 0..8 {
            assert_eq!(d.get(r, 0), 0.0, "row {r}");
        }
    }

    #[test]
    fn sparse_unstandardize_prediction_identity() {
        let mut rng = Rng::seed_from(66);
        let dense = Mat::from_fn(15, 3, |_, _| {
            if rng.bernoulli(0.6) {
                rng.normal_ms(3.0, 2.0)
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..15).map(|_| rng.normal()).collect();
        let (ds, _, st) = standardize_design(&Design::from(Csr::from_dense(&dense, 0.0)), &y);
        let beta_std = vec![0.3, -0.7, 0.2];
        let (beta_orig, intercept) = st.unstandardize(&beta_std);
        // the sparse matrix keeps its column means, so the implicit
        // centering term Σ β_j·x̄_j/σ_j reconciles the parameterizations:
        // (Xs·β − Σ β x̄/σ) + ȳ == X·β_orig + intercept
        let mean_term: f64 = beta_std
            .iter()
            .zip(&st.x_mean)
            .zip(&st.x_scale)
            .map(|((b, m), s)| if *s > 1e-12 { b * m / s } else { 0.0 })
            .sum();
        let pred_std = ds.matvec(&beta_std);
        let pred_orig = dense.matvec(&beta_orig);
        for i in 0..15 {
            let a = pred_std[i] - mean_term + st.y_mean;
            let b = pred_orig[i] + intercept;
            assert!((a - b).abs() < 1e-8, "i={i}: {a} vs {b}");
        }
    }
}
