//! Data layer: synthetic generators, the paper's twelve data-set profiles,
//! standardization and svmlight-format IO.
//!
//! The paper evaluates on real data sets (GLI-85 … E2006-tfidf for p ≫ n;
//! MITFaces … FD for n ≫ p) that are not available offline; per the
//! substitution policy in DESIGN.md §3, [`profiles`] generates synthetic
//! equivalents matched in sample/feature regime, correlation structure,
//! sparsity and signal-to-noise — the properties the timing figures
//! actually exercise.

pub mod profiles;
pub mod standardize;
pub mod svmlight;
pub mod synth;

pub use profiles::{profile_by_name, DatasetProfile, Regime, ALL_PROFILES};
pub use standardize::{standardize, standardize_design, Standardization};
pub use synth::{prostate_like, synth_regression, SynthSpec};

use crate::linalg::Mat;

/// A regression data set ready for the solvers: standardized design and
/// centered response.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Mat,
    pub y: Vec<f64>,
    /// Ground-truth coefficients when synthetic (for recovery metrics).
    pub beta_true: Option<Vec<f64>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn p(&self) -> usize {
        self.x.cols()
    }
}
