//! Synthetic regression problem generators.
//!
//! `synth_regression` draws a correlated Gaussian design with a sparse
//! ground-truth coefficient vector and Gaussian noise — the classic
//! Elastic-Net testbed (Zou & Hastie 2005 §5 use the same construction).
//! Correlation is induced by an AR(1)-style mixing so that groups of
//! features are strongly correlated, which is exactly the regime where
//! the Elastic Net's grouping effect (and the paper's λ₂ > 0 case)
//! matters.

use super::{standardize::standardize_opts, Dataset};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Specification for a synthetic regression data set.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub n: usize,
    pub p: usize,
    /// Number of truly non-zero coefficients.
    pub support: usize,
    /// AR(1) feature correlation in [0, 1).
    pub rho: f64,
    /// Fraction of entries kept (1.0 = dense design).
    pub density: f64,
    /// Signal-to-noise ratio ‖Xβ‖/‖ε‖.
    pub snr: f64,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            name: "synth".into(),
            n: 100,
            p: 200,
            support: 10,
            rho: 0.5,
            density: 1.0,
            snr: 3.0,
            seed: 0,
        }
    }
}

/// Generate a standardized synthetic regression data set per `spec`.
pub fn synth_regression(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::seed_from(spec.seed ^ 0x5EED_DA7A);
    let (n, p) = (spec.n, spec.p);

    // AR(1)-correlated rows: x_{j} = ρ·x_{j−1} + √(1−ρ²)·z_j keeps unit
    // marginal variance while corr(x_j, x_k) = ρ^{|j−k|}.
    let rho = spec.rho.clamp(0.0, 0.999);
    let mix = (1.0 - rho * rho).sqrt();
    let mut x = Mat::zeros(n, p);
    for r in 0..n {
        let row = x.row_mut(r);
        let mut prev = rng.normal();
        row[0] = prev;
        for j in 1..p {
            prev = rho * prev + mix * rng.normal();
            row[j] = prev;
        }
    }

    // Sparsify (masking preserves correlation among surviving entries —
    // mirrors TF-IDF-style designs like Dorothea/E2006).
    if spec.density < 1.0 {
        for v in x.data_mut().iter_mut() {
            if rng.uniform() >= spec.density {
                *v = 0.0;
            }
        }
    }

    // Sparse ground truth with alternating-sign, decaying amplitudes on a
    // random support.
    let mut beta = vec![0.0; p];
    let support = spec.support.min(p);
    let idx = rng.sample_indices(p, support);
    for (k, &j) in idx.iter().enumerate() {
        let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
        beta[j] = sign * (1.0 + 1.0 / (1.0 + k as f64));
    }

    // Response with calibrated SNR.
    let signal = x.matvec(&beta);
    let signal_norm = crate::linalg::vecops::norm2(&signal).max(1e-12);
    let mut noise = rng.normal_vec(n);
    let noise_norm = crate::linalg::vecops::norm2(&noise).max(1e-12);
    let scale = signal_norm / (spec.snr.max(1e-6) * noise_norm);
    for v in noise.iter_mut() {
        *v *= scale;
    }
    let y: Vec<f64> = signal.iter().zip(&noise).map(|(s, e)| s + e).collect();

    // Sparse designs skip centering so zeros survive (glmnet convention).
    let (xs, ys, _std) = standardize_opts(&x, &y, spec.density >= 1.0);
    Dataset { name: spec.name.clone(), x: xs, y: ys, beta_true: Some(beta) }
}

/// A prostate-cancer-like set for Figure 1: n = 97, p = 8 correlated
/// clinical-style features (the real set's shape from Zou & Hastie 2005),
/// with a dense moderate-amplitude ground truth so the regularization path
/// shows the classic staggered feature entry.
pub fn prostate_like(seed: u64) -> Dataset {
    let spec = SynthSpec {
        name: "prostate".into(),
        n: 97,
        p: 8,
        support: 8,
        rho: 0.35,
        density: 1.0,
        snr: 4.0,
        seed,
    };
    let mut d = synth_regression(&spec);
    // Dampen half the coefficients so features enter the path at clearly
    // separated budgets (visual match to the paper's Fig 1 structure).
    if let Some(bt) = &mut d.beta_true {
        for (j, b) in bt.iter_mut().enumerate() {
            if j % 2 == 1 {
                *b *= 0.25;
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops;

    #[test]
    fn shapes_and_standardization() {
        let d = synth_regression(&SynthSpec { n: 40, p: 17, ..Default::default() });
        assert_eq!(d.n(), 40);
        assert_eq!(d.p(), 17);
        // y centered
        assert!(vecops::mean(&d.y).abs() < 1e-10);
        // columns unit-norm (standardize scales to ‖col‖² = n)
        for c in 0..17 {
            let col = d.x.col(c);
            assert!((vecops::norm2_sq(&col) - 40.0).abs() < 1e-8, "col {c}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synth_regression(&SynthSpec { seed: 9, ..Default::default() });
        let b = synth_regression(&SynthSpec { seed: 9, ..Default::default() });
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        let c = synth_regression(&SynthSpec { seed: 10, ..Default::default() });
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn support_size_respected() {
        let d = synth_regression(&SynthSpec { p: 50, support: 7, ..Default::default() });
        let nnz = d.beta_true.unwrap().iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 7);
    }

    #[test]
    fn sparse_design_has_zeros() {
        let d = synth_regression(&SynthSpec {
            n: 50,
            p: 60,
            density: 0.1,
            ..Default::default()
        });
        // Standardization rescales but zeros stay zero.
        let zeros = d.x.data().iter().filter(|v| **v == 0.0).count();
        assert!(zeros > 50 * 60 / 2, "zeros={zeros}");
    }

    #[test]
    fn prostate_like_shape() {
        let d = prostate_like(0);
        assert_eq!((d.n(), d.p()), (97, 8));
    }

    #[test]
    fn correlation_increases_with_rho() {
        let lo = synth_regression(&SynthSpec { n: 400, rho: 0.0, ..Default::default() });
        let hi = synth_regression(&SynthSpec { n: 400, rho: 0.9, ..Default::default() });
        let corr = |d: &Dataset| {
            let a = d.x.col(0);
            let b = d.x.col(1);
            vecops::dot(&a, &b) / (vecops::norm2(&a) * vecops::norm2(&b))
        };
        assert!(corr(&hi).abs() > corr(&lo).abs() + 0.3);
    }
}
