//! The paper's twelve evaluation data sets as synthetic profiles.
//!
//! Eight p ≫ n sets (Figure 2) and four n ≫ p sets (Figure 3). Real
//! downloads are unavailable offline, so each profile records the regime
//! and structural knobs (shape, density, correlation, support) of its
//! namesake, scaled so the full 12×40-setting benchmark grid finishes on
//! one machine (cap ≈ 2·10⁷ dense design entries — the *relative* timing
//! geometry between solvers is preserved; see DESIGN.md §3).

use super::synth::{synth_regression, SynthSpec};
use super::Dataset;

/// Which side of the paper's evaluation a set belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Figure 2: many more features than samples.
    PGreaterN,
    /// Figure 3: many more samples than features.
    NGreaterP,
}

/// A named data-set profile.
#[derive(Clone, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// The real set's shape, for reporting.
    pub paper_n: usize,
    pub paper_p: usize,
    /// Our scaled shape.
    pub n: usize,
    pub p: usize,
    pub support: usize,
    pub rho: f64,
    pub density: f64,
    pub snr: f64,
    pub regime: Regime,
    /// One-line provenance of the namesake.
    pub about: &'static str,
}

impl DatasetProfile {
    /// Materialize the profile (deterministic in `seed`).
    pub fn generate(&self, seed: u64) -> Dataset {
        synth_regression(&SynthSpec {
            name: self.name.to_string(),
            n: self.n,
            p: self.p,
            support: self.support,
            rho: self.rho,
            density: self.density,
            snr: self.snr,
            seed: seed ^ fnv(self.name),
        })
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// All twelve profiles, paper order: eight p ≫ n then four n ≫ p.
pub const ALL_PROFILES: [DatasetProfile; 12] = [
    // ---- Figure 2: p >> n ------------------------------------------------
    DatasetProfile {
        name: "GLI-85",
        paper_n: 85, paper_p: 22283,
        n: 85, p: 6000, support: 40, rho: 0.6, density: 1.0, snr: 3.0,
        regime: Regime::PGreaterN,
        about: "glioma transcriptional profiling (smallest set; GPU transfer not amortized in the paper)",
    },
    DatasetProfile {
        name: "SMK-CAN-187",
        paper_n: 187, paper_p: 19993,
        n: 187, p: 8000, support: 60, rho: 0.6, density: 1.0, snr: 3.0,
        regime: Regime::PGreaterN,
        about: "smoker lung-cancer gene expression",
    },
    DatasetProfile {
        name: "GLA-BRA-180",
        paper_n: 180, paper_p: 49151,
        n: 180, p: 10000, support: 70, rho: 0.65, density: 1.0, snr: 3.0,
        regime: Regime::PGreaterN,
        about: "glioma grade analysis",
    },
    DatasetProfile {
        name: "Arcene",
        paper_n: 100, paper_p: 10000,
        n: 100, p: 10000, support: 50, rho: 0.5, density: 0.54, snr: 2.5,
        regime: Regime::PGreaterN,
        about: "NIPS'03 feature selection: cancer vs normal mass-spectrometry",
    },
    DatasetProfile {
        name: "Dorothea",
        paper_n: 800, paper_p: 100000,
        n: 400, p: 20000, support: 80, rho: 0.3, density: 0.009, snr: 2.0,
        regime: Regime::PGreaterN,
        about: "NIPS'03: thrombin binding, extremely sparse binary features",
    },
    DatasetProfile {
        name: "Scene15",
        paper_n: 300, paper_p: 35840,
        n: 300, p: 12000, support: 90, rho: 0.5, density: 0.7, snr: 3.0,
        regime: Regime::PGreaterN,
        about: "scene recognition (classes 6/7), spatial-pyramid features",
    },
    DatasetProfile {
        name: "PEMS",
        paper_n: 267, paper_p: 138672,
        n: 267, p: 16000, support: 100, rho: 0.8, density: 1.0, snr: 4.0,
        regime: Regime::PGreaterN,
        about: "SF bay-area freeway lane occupancy rates (strongly correlated sensors)",
    },
    DatasetProfile {
        name: "E2006-tfidf",
        paper_n: 3308, paper_p: 150360,
        n: 800, p: 24000, support: 120, rho: 0.2, density: 0.004, snr: 2.0,
        regime: Regime::PGreaterN,
        about: "financial-report risk, sparse TF-IDF text features",
    },
    // ---- Figure 3: n >> p ------------------------------------------------
    DatasetProfile {
        name: "MITFaces",
        paper_n: 489410, paper_p: 361,
        n: 40000, p: 361, support: 60, rho: 0.7, density: 1.0, snr: 3.0,
        regime: Regime::NGreaterP,
        about: "face recognition patches (19×19 pixels)",
    },
    DatasetProfile {
        name: "Yahoo-LTR",
        paper_n: 473134, paper_p: 700,
        n: 30000, p: 700, support: 90, rho: 0.4, density: 0.7, snr: 3.0,
        regime: Regime::NGreaterP,
        about: "learning-to-rank web search features",
    },
    DatasetProfile {
        name: "YearPredictionMSD",
        paper_n: 463715, paper_p: 90,
        n: 60000, p: 90, support: 45, rho: 0.5, density: 1.0, snr: 3.0,
        regime: Regime::NGreaterP,
        about: "song release year from audio features",
    },
    DatasetProfile {
        name: "FD",
        paper_n: 400000, paper_p: 900,
        n: 20000, p: 900, support: 120, rho: 0.6, density: 1.0, snr: 3.0,
        regime: Regime::NGreaterP,
        about: "face detection (paper: glmnet ran out of memory here)",
    },
];

/// Look a profile up by (case-insensitive) name.
pub fn profile_by_name(name: &str) -> Option<&'static DatasetProfile> {
    ALL_PROFILES.iter().find(|p| p.name.eq_ignore_ascii_case(name))
}

/// The Figure-2 profiles.
pub fn p_gg_n() -> Vec<&'static DatasetProfile> {
    ALL_PROFILES.iter().filter(|p| p.regime == Regime::PGreaterN).collect()
}

/// The Figure-3 profiles.
pub fn n_gg_p() -> Vec<&'static DatasetProfile> {
    ALL_PROFILES.iter().filter(|p| p.regime == Regime::NGreaterP).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_profiles_split_eight_four() {
        assert_eq!(ALL_PROFILES.len(), 12);
        assert_eq!(p_gg_n().len(), 8);
        assert_eq!(n_gg_p().len(), 4);
    }

    #[test]
    fn regimes_are_consistent_with_shapes() {
        for prof in &ALL_PROFILES {
            match prof.regime {
                Regime::PGreaterN => assert!(prof.p > prof.n, "{}", prof.name),
                Regime::NGreaterP => assert!(prof.n > prof.p, "{}", prof.name),
            }
        }
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(profile_by_name("arcene").is_some());
        assert!(profile_by_name("ARCENE").is_some());
        assert!(profile_by_name("nope").is_none());
    }

    #[test]
    fn generation_matches_profile_shape() {
        let prof = profile_by_name("GLI-85").unwrap();
        let d = prof.generate(1);
        assert_eq!((d.n(), d.p()), (prof.n, prof.p));
    }

    #[test]
    fn sparse_profiles_generate_sparse_designs() {
        let prof = profile_by_name("Dorothea").unwrap();
        let d = prof.generate(1);
        let zeros = d.x.data().iter().filter(|v| **v == 0.0).count() as f64;
        let frac = zeros / (d.n() * d.p()) as f64;
        assert!(frac > 0.95, "zero fraction {frac}");
    }

    #[test]
    fn budget_cap_respected() {
        for prof in &ALL_PROFILES {
            assert!(prof.n * prof.p <= 25_000_000, "{} too large", prof.name);
        }
    }
}
