//! svmlight/libsvm format IO (`label idx:val idx:val ...`, 1-based
//! indices) — the interchange format the paper's comparator software
//! (liblinear, Shotgun) consumes, so data sets generated here can be
//! round-tripped to disk and shared.

use crate::linalg::{Csr, Design, Mat};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Write `(x, y)` in svmlight format. Zero entries are omitted.
pub fn write_svmlight(path: &Path, x: &Mat, y: &[f64]) -> Result<()> {
    assert_eq!(x.rows(), y.len());
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for r in 0..x.rows() {
        write!(w, "{}", fmt_num(y[r]))?;
        for (j, &v) in x.row(r).iter().enumerate() {
            if v != 0.0 {
                write!(w, " {}:{}", j + 1, fmt_num(v))?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

fn fmt_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.17}")
    }
}

/// Read an svmlight file into a sparse design + response. `p_hint` can
/// force a minimum feature count (files may omit trailing features).
pub fn read_svmlight(path: &Path, p_hint: usize) -> Result<(Csr, Vec<f64>)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut trip = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("bad label at line {}", lineno + 1))?;
        let row = y.len();
        y.push(label);
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("bad pair '{tok}' at line {}", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("bad index at line {}", lineno + 1))?;
            if idx == 0 {
                bail!("svmlight indices are 1-based; got 0 at line {}", lineno + 1);
            }
            let val: f64 = val
                .parse()
                .with_context(|| format!("bad value at line {}", lineno + 1))?;
            max_col = max_col.max(idx);
            trip.push((row, idx - 1, val));
        }
    }
    let p = max_col.max(p_hint);
    Ok((Csr::from_triplets(y.len(), p, trip), y))
}

/// Read an svmlight file straight into a solver-ready sparse [`Design`]
/// (CSR plus its parallel-built CSC mirror) — the entry point of the
/// never-densify path: the returned design runs glmnet CD, Shotgun and
/// SVEN at O(nnz) with no n × p dense matrix ever allocated.
pub fn read_design(path: &Path, p_hint: usize) -> Result<(Design, Vec<f64>)> {
    let (csr, y) = read_svmlight(path, p_hint)?;
    Ok((Design::from(csr), y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_dense() {
        let mut rng = Rng::seed_from(71);
        let x = Mat::from_fn(9, 5, |_, _| {
            if rng.bernoulli(0.6) { rng.normal() } else { 0.0 }
        });
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let dir = std::env::temp_dir().join("sven_svmlight_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.svm");
        write_svmlight(&path, &x, &y).unwrap();
        let (xr, yr) = read_svmlight(&path, 5).unwrap();
        assert_eq!(xr.rows(), 9);
        assert_eq!(xr.cols(), 5);
        let xd = xr.to_dense();
        for r in 0..9 {
            assert!((yr[r] - y[r]).abs() < 1e-12);
            for c in 0..5 {
                assert!((xd.get(r, c) - x.get(r, c)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn rejects_zero_index() {
        let dir = std::env::temp_dir().join("sven_svmlight_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.svm");
        std::fs::write(&path, "1.0 0:3.5\n").unwrap();
        assert!(read_svmlight(&path, 0).is_err());
    }

    #[test]
    fn read_design_is_sparse() {
        let dir = std::env::temp_dir().join("sven_svmlight_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("design.svm");
        std::fs::write(&path, "1.0 1:2.0 3:1.0\n-1.0 2:4.0\n").unwrap();
        let (d, y) = read_design(&path, 3).unwrap();
        assert!(d.is_sparse());
        assert_eq!((d.rows(), d.cols()), (2, 3));
        assert_eq!(d.nnz(), 3);
        assert_eq!(y, vec![1.0, -1.0]);
        let out = d.matvec(&[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![3.0, 4.0]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let dir = std::env::temp_dir().join("sven_svmlight_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comments.svm");
        std::fs::write(&path, "# header\n\n2.5 2:1.0 # trailing\n").unwrap();
        let (x, y) = read_svmlight(&path, 0).unwrap();
        assert_eq!(y, vec![2.5]);
        assert_eq!(x.cols(), 2);
        assert_eq!(x.to_dense().get(0, 1), 1.0);
    }
}
