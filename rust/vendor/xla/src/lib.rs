//! Offline stub of the `xla` PJRT bindings.
//!
//! The build container has no PJRT plugin, so every entry point returns
//! [`XlaError`] with a clear message. Callers already gate on these
//! results: `XlaBackend::from_default_dir()` fails cleanly, the figure
//! benches print "SVEN (XLA) unavailable" and continue with the CPU
//! backend, and `rust/tests/runtime_xla.rs` skips when artifacts are
//! absent. Replacing this stub with the real `xla` crate re-enables the
//! PJRT path without touching any caller.

use std::fmt;
use std::path::Path;

/// Error type for every stubbed operation.
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn unavailable(what: &str) -> Self {
        XlaError(format!(
            "{what}: XLA/PJRT is unavailable in this build (offline stub; \
             link the real `xla` crate to enable the PJRT backend)"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(XlaError::unavailable(&format!(
            "parsing {}",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation handle.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("fetching buffer"))
    }
}

/// Host-side literal value.
pub struct Literal(());

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("reading literal"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::unavailable("untupling literal"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("executing"))
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    /// The stub cannot create a client; every caller degrades from here.
    pub fn cpu() -> Result<Self> {
        Err(XlaError::unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("compiling"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::unavailable("staging buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn hlo_parse_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
