//! Offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so this
//! vendored shim provides the exact API subset the crate uses: the
//! type-erased [`Error`], the [`Result`] alias, the [`anyhow!`] /
//! [`bail!`] macros, and the [`Context`] extension trait for `Result`
//! and `Option`. Semantics mirror the real crate closely enough that
//! swapping the registry dependency back in is a one-line change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a standard error, keeping it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prepend context to the message (the `Context` trait calls this).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// Borrow the source chain root, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if let Some(src) = &self.source {
            write!(f, "\n\nCaused by:\n    {src}")?;
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Attach a message to the error variant.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    /// Attach a lazily-built message to the error variant.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| "no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 7;
        let e = anyhow!("got {n} and {}", 8);
        assert_eq!(e.to_string(), "got 7 and 8");
        fn bails() -> Result<()> {
            bail!("boom {}", 1);
        }
        assert_eq!(bails().unwrap_err().to_string(), "boom 1");
    }
}
