//! Runtime integration: load real artifacts, execute via PJRT, and check
//! the XLA backend agrees with the in-process rust backend — the
//! cross-layer correctness seal of the whole stack.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use sven::data::{synth_regression, SynthSpec};
use sven::linalg::vecops;
use sven::runtime::{XlaBackend, XlaEngine};
use sven::solvers::elastic_net::EnProblem;
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::sven::{RustBackend, Sven, SvenConfig, SvmMode};

fn engine_or_skip() -> Option<XlaBackend> {
    let dir = sven::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaBackend::new(std::sync::Arc::new(
        XlaEngine::load(&dir).expect("engine load"),
    )))
}

fn problem(n: usize, p: usize, seed: u64, frac: f64) -> Option<EnProblem> {
    let d = synth_regression(&SynthSpec {
        n,
        p,
        support: p.min(8),
        seed,
        ..Default::default()
    });
    let kappa = 0.5;
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, kappa) * frac;
    let g = glmnet::solve_penalized(
        &d.x,
        &d.y,
        lambda,
        &GlmnetConfig { kappa, tol: 1e-13, ..Default::default() },
        None,
    );
    let t = vecops::norm1(&g.beta);
    if t <= 1e-10 {
        return None;
    }
    let lambda2 = n as f64 * lambda * (1.0 - kappa);
    Some(EnProblem::new(d.x, d.y, t, lambda2))
}

#[test]
fn xla_primal_matches_rust_backend() {
    let Some(backend) = engine_or_skip() else { return };
    // p ≫ n ⇒ primal path; (20, 40) fits the (32, 64) bucket with padding.
    let prob = problem(20, 40, 1771, 0.3).expect("active problem");
    let xla = Sven::new(backend);
    let rust = Sven::new(RustBackend::default());
    let bx = xla.solve(&prob).expect("xla solve");
    let br = rust.solve(&prob).expect("rust solve");
    for j in 0..prob.p() {
        assert!(
            (bx.beta[j] - br.beta[j]).abs() < 1e-6,
            "j={j}: xla {} vs rust {}",
            bx.beta[j],
            br.beta[j]
        );
    }
}

#[test]
fn xla_dual_matches_rust_backend() {
    let Some(backend) = engine_or_skip() else { return };
    // n ≫ p ⇒ dual path; (150, 12) fits gram (256, 16) + dual p=16.
    let prob = problem(150, 12, 1772, 0.25).expect("active problem");
    let xla = Sven::new(backend);
    let rust = Sven::new(RustBackend::default());
    let bx = xla.solve(&prob).expect("xla solve");
    let br = rust.solve(&prob).expect("rust solve");
    for j in 0..prob.p() {
        assert!(
            (bx.beta[j] - br.beta[j]).abs() < 1e-6,
            "j={j}: xla {} vs rust {}",
            bx.beta[j],
            br.beta[j]
        );
    }
}

#[test]
fn xla_prepared_path_reuse_and_warm_start() {
    let Some(backend) = engine_or_skip() else { return };
    let prob = problem(120, 10, 1773, 0.3).expect("active problem");
    let sven = Sven::new(backend);
    let prep = sven.prepare_shared(&prob.x, &prob.y).expect("prepare");
    let mut scratch = sven::solvers::sven::SvmScratch::new();
    // three budgets, warm-starting each from the previous α
    let mut warm: Option<sven::solvers::sven::SvmWarm> = None;
    for scale in [0.6, 0.8, 1.0] {
        let p2 = prob.with_budget(prob.t * scale, prob.lambda2);
        let sol = sven
            .solve_prepared(prep.as_ref(), &mut scratch, &p2, warm.as_ref(), None)
            .expect("prepared solve");
        let oneshot = sven.solve(&p2).expect("oneshot");
        for j in 0..p2.p() {
            assert!(
                (sol.beta[j] - oneshot.beta[j]).abs() < 1e-6,
                "scale {scale} j={j}"
            );
        }
        warm = Some(sven::solvers::sven::SvmWarm {
            w: None,
            alpha: None, // warm-start plumbed; exact values checked above
        });
    }
}

#[test]
fn xla_forced_modes_agree() {
    let Some(backend) = engine_or_skip() else { return };
    let prob = problem(60, 14, 1774, 0.3).expect("active problem");
    let primal = Sven::with_config(
        backend.clone(),
        SvenConfig { mode: SvmMode::Primal, ..Default::default() },
    );
    let dual = Sven::with_config(
        backend,
        SvenConfig { mode: SvmMode::Dual, ..Default::default() },
    );
    let bp = primal.solve(&prob).expect("primal").beta;
    let bd = dual.solve(&prob).expect("dual").beta;
    for j in 0..prob.p() {
        assert!((bp[j] - bd[j]).abs() < 1e-6, "j={j}: {} vs {}", bp[j], bd[j]);
    }
}

#[test]
fn compile_cache_hits_after_warm() {
    let Some(backend) = engine_or_skip() else { return };
    let prob = problem(20, 30, 1775, 0.3).expect("active problem");
    let sven = Sven::new(backend.clone());
    let _ = sven.solve(&prob).expect("first");
    let (h0, m0) = backend.engine().cache_stats();
    let _ = sven.solve(&prob).expect("second");
    let (h1, m1) = backend.engine().cache_stats();
    assert_eq!(m1, m0, "no new compilations on repeat solve");
    assert!(h1 > h0, "cache hits must increase");
}

