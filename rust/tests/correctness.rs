//! Cross-solver correctness: every solver in the repo must agree on the
//! same problems — the paper's §5 "Correctness" claim, system-wide.

use sven::data::{synth_regression, SynthSpec};
use sven::linalg::vecops;
use sven::solvers::elastic_net::{penalized_to_constrained, EnProblem};
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::l1ls::{solve_l1ls, L1LsConfig};
use sven::solvers::shotgun::{solve_shotgun, ShotgunConfig};
use sven::solvers::sven::{RustBackend, Sven};

/// Solve one grid point with every applicable solver and cross-check.
fn cross_check(n: usize, p: usize, seed: u64, kappa: f64, frac: f64) {
    let d = synth_regression(&SynthSpec { n, p, support: 8.min(p), seed, ..Default::default() });
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, kappa) * frac;
    let cfg = GlmnetConfig { kappa, tol: 1e-12, ..Default::default() };
    let reference = glmnet::solve_penalized(&d.x, &d.y, lambda, &cfg, None);
    if vecops::norm1(&reference.beta) < 1e-10 {
        return;
    }

    // Shotgun (any κ)
    let s = solve_shotgun(
        &d.x,
        &d.y,
        lambda,
        &ShotgunConfig { kappa, tol: 1e-12, ..Default::default() },
        None,
    );
    for j in 0..p {
        assert!(
            (s.beta[j] - reference.beta[j]).abs() < 5e-4,
            "shotgun[{j}] {} vs {}",
            s.beta[j],
            reference.beta[j]
        );
    }

    // L1_LS (Lasso only)
    if (kappa - 1.0).abs() < 1e-12 {
        let l = solve_l1ls(&d.x, &d.y, lambda, &L1LsConfig { tol: 1e-10, ..Default::default() });
        for j in 0..p {
            assert!(
                (l.beta[j] - reference.beta[j]).abs() < 1e-3,
                "l1ls[{j}] {} vs {}",
                l.beta[j],
                reference.beta[j]
            );
        }
    }

    // SVEN (both constrained-form params from the paper protocol)
    let (t, lambda2) = penalized_to_constrained(&reference.beta, lambda, kappa, n);
    if lambda2 > 0.0 {
        let prob = EnProblem::new(d.x.clone(), d.y.clone(), t, lambda2);
        let sven = Sven::new(RustBackend::default());
        let sol = sven.solve(&prob).unwrap();
        for j in 0..p {
            assert!(
                (sol.beta[j] - reference.beta[j]).abs() < 1e-4,
                "sven[{j}] {} vs {}",
                sol.beta[j],
                reference.beta[j]
            );
        }
        // KKT residual of the constrained problem must be near-zero.
        let kkt = prob.kkt_residual(&sol.beta);
        assert!(kkt < 1e-3, "kkt residual {kkt}");
    }
}

#[test]
fn all_solvers_agree_wide() {
    cross_check(25, 60, 501, 0.5, 0.3);
}

#[test]
fn all_solvers_agree_tall() {
    cross_check(150, 12, 502, 0.5, 0.3);
}

#[test]
fn all_solvers_agree_lasso() {
    cross_check(40, 30, 503, 1.0, 0.4);
}

#[test]
fn all_solvers_agree_heavy_ridge() {
    cross_check(35, 25, 504, 0.2, 0.3);
}

#[test]
fn sven_handles_correlated_features() {
    // strong correlation: the elastic net's grouping-effect regime
    let d = synth_regression(&SynthSpec {
        n: 40,
        p: 60,
        support: 6,
        rho: 0.95,
        seed: 505,
        ..Default::default()
    });
    let kappa = 0.5;
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, kappa) * 0.25;
    let reference = glmnet::solve_penalized(
        &d.x,
        &d.y,
        lambda,
        &GlmnetConfig { kappa, tol: 1e-12, ..Default::default() },
        None,
    );
    let (t, lambda2) = penalized_to_constrained(&reference.beta, lambda, kappa, 40);
    if t < 1e-10 {
        return;
    }
    let sol = Sven::new(RustBackend::default())
        .solve(&EnProblem::new(d.x, d.y, t, lambda2))
        .unwrap();
    for j in 0..60 {
        assert!((sol.beta[j] - reference.beta[j]).abs() < 1e-4, "j={j}");
    }
}

#[test]
fn path_sweep_matches_everywhere() {
    use sven::coordinator::{path::max_deviation, PathRunner, PathRunnerConfig};
    let d = synth_regression(&SynthSpec {
        n: 50,
        p: 80,
        support: 10,
        seed: 506,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 15, ..Default::default() });
    let results = runner
        .derive_and_run(&d, &Sven::new(RustBackend::default()))
        .unwrap();
    assert!(results.len() >= 5, "grid too small: {}", results.len());
    let dev = max_deviation(&results);
    assert!(dev < 5e-4, "path deviation {dev}");
}
