//! Whole-system integration: data layer → path protocol → coordinator
//! service → solution quality, plus CLI plumbing and IO round trips.

use std::sync::Arc;
use sven::coordinator::{BackendChoice, PathRunner, PathRunnerConfig, Service, ServiceConfig};
use sven::coordinator::PoolConfig;
use sven::data::{profile_by_name, synth_regression, SynthSpec};
use sven::solvers::sven::{RustBackend, Sven};

#[test]
fn profile_to_path_to_solution() {
    // Use a scaled-down profile-like dataset for CI speed.
    let d = synth_regression(&SynthSpec {
        name: "GLI-85-mini".into(),
        n: 40,
        p: 300,
        support: 12,
        rho: 0.6,
        seed: 701,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 10, ..Default::default() });
    let results = runner
        .derive_and_run(&d, &Sven::new(RustBackend::default()))
        .unwrap();
    assert!(!results.is_empty());
    // supports grow along the grid, deviations stay tiny
    assert!(results.windows(2).all(|w| w[0].nnz <= w[1].nnz + 2));
    assert!(results.iter().all(|r| r.max_dev < 5e-4));
}

#[test]
fn service_full_grid_both_datasets() {
    let wide = synth_regression(&SynthSpec {
        n: 30, p: 80, support: 8, seed: 702, ..Default::default()
    });
    let tall = synth_regression(&SynthSpec {
        n: 200, p: 12, support: 5, seed: 703, ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 6, ..Default::default() });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 3, queue_capacity: 8 },
        ..Default::default()
    });
    let mut receivers = Vec::new();
    for (id, d) in [(1u64, &wide), (2, &tall)] {
        let grid = runner.derive_grid(d);
        assert!(!grid.is_empty());
        let x = Arc::new(sven::linalg::Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        for pt in &grid {
            receivers.push((
                pt.beta.clone(),
                service
                    .submit_point(
                        id,
                        x.clone(),
                        y.clone(),
                        pt.t,
                        pt.lambda2.max(1e-6),
                        BackendChoice::Rust,
                    )
                    .expect("service accepting jobs"),
            ));
        }
    }
    for (beta_ref, rx) in receivers {
        let out = rx.recv().unwrap();
        let sol = out.result.expect("solve ok").expect_point();
        let dev = sol
            .beta
            .iter()
            .zip(&beta_ref)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(dev < 1e-3, "dev {dev}");
    }
    assert_eq!(service.metrics().failed(), 0);
    service.shutdown();
}

#[test]
fn dataset_profiles_generate_and_standardize() {
    for name in ["GLI-85", "YearPredictionMSD"] {
        let prof = profile_by_name(name).unwrap();
        // tiny seed-stable generation sanity (full size covered in benches)
        let d = prof.generate(1);
        assert_eq!(d.n(), prof.n);
        assert_eq!(d.p(), prof.p);
        assert!(sven::linalg::vecops::mean(&d.y).abs() < 1e-8);
    }
}

#[test]
fn svmlight_roundtrip_through_solver() {
    let d = synth_regression(&SynthSpec {
        n: 25,
        p: 15,
        support: 4,
        seed: 704,
        ..Default::default()
    });
    let dir = std::env::temp_dir().join("sven_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.svm");
    sven::data::svmlight::write_svmlight(&path, &d.x, &d.y).unwrap();
    let (xr, yr) = sven::data::svmlight::read_svmlight(&path, 15).unwrap();
    let xd = xr.to_dense();
    // solving the round-tripped data gives the same path
    let runner = PathRunner::new(PathRunnerConfig { grid: 4, ..Default::default() });
    let orig = runner.derive_grid(&d);
    let rt_data = sven::data::Dataset { name: "rt".into(), x: xd, y: yr, beta_true: None };
    let rt = runner.derive_grid(&rt_data);
    assert_eq!(orig.len(), rt.len());
    for (a, b) in orig.iter().zip(&rt) {
        assert!((a.t - b.t).abs() < 1e-10);
    }
}

#[test]
fn cli_arg_parsing_smoke() {
    let args = sven::cli::parse_args(&[
        "--dataset".into(),
        "Arcene".into(),
        "--grid".into(),
        "12".into(),
    ])
    .unwrap();
    assert_eq!(args.get("dataset"), Some("Arcene"));
    assert_eq!(args.get_usize("grid").unwrap(), Some(12));
}

#[test]
fn slack_budget_warning_path() {
    let d = synth_regression(&SynthSpec {
        n: 60,
        p: 8,
        support: 4,
        seed: 705,
        ..Default::default()
    });
    let sven = Sven::new(RustBackend::default());
    let huge = sven::solvers::elastic_net::EnProblem::new(d.x.clone(), d.y.clone(), 1e7, 0.5);
    assert!(sven.budget_is_slack(&huge));
    let tiny = sven::solvers::elastic_net::EnProblem::new(d.x, d.y, 1e-2, 0.5);
    assert!(!sven.budget_is_slack(&tiny));
}
