//! The never-densify seal on the sparse execution path.
//!
//! A counting global allocator records the largest single heap request.
//! We write an svmlight data set whose dense form would be one ~38 MB
//! allocation (n=1200 × p=4000 f64), then run the whole pipeline —
//! loader → sparse `Design` → glmnet CD → SVEN (primal) — and assert no
//! allocation ever came within 10× of the dense matrix. If any layer
//! regressed into densifying (`to_dense`, a materialized reduction, a
//! dense transposed copy), the test fails on the allocation budget, not
//! on a timing heuristic.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use sven::data::svmlight;
use sven::linalg::vecops;
use sven::rng::Rng;
use sven::solvers::elastic_net::EnProblem;
use sven::solvers::glmnet::{self, CdMode, GlmnetConfig};
use sven::solvers::shotgun::{solve_shotgun_design, ShotgunConfig};
use sven::solvers::sven::{RustBackend, Sven};

/// Tracks the largest single allocation request since the last reset.
struct MaxTrackingAlloc;

static LARGEST: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for MaxTrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        LARGEST.fetch_max(layout.size(), Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LARGEST.fetch_max(new_size, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: MaxTrackingAlloc = MaxTrackingAlloc;

const N: usize = 1200;
const P: usize = 4000; // 2p > n ⇒ SVEN auto-resolves to the primal solver
const NNZ_PER_ROW: usize = 16; // density 0.004, the Dorothea regime

/// One test fn (not several) so no concurrent test pollutes the
/// allocation high-water mark.
#[test]
fn sparse_pipeline_never_densifies() {
    let dense_bytes = N * P * std::mem::size_of::<f64>(); // ~38.4 MB
    let budget = dense_bytes / 10; // ~3.8 MB, >10x any legit sparse alloc

    // --- write a sparse svmlight data set (setup, untracked) -----------
    let dir = std::env::temp_dir().join("sven_no_densify");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sparse.svm");
    let mut rng = Rng::seed_from(7777);
    let mut file = String::with_capacity(N * NNZ_PER_ROW * 16);
    for _ in 0..N {
        file.push_str(&format!("{:.6}", rng.normal()));
        let mut cols = rng.sample_indices(P, NNZ_PER_ROW);
        cols.sort_unstable();
        for c in cols {
            file.push_str(&format!(" {}:{:.6}", c + 1, rng.normal()));
        }
        file.push('\n');
    }
    std::fs::write(&path, &file).unwrap();
    drop(file);

    // --- tracked region: loader → CD → Shotgun → SVEN -------------------
    LARGEST.store(0, Ordering::Relaxed);

    let (design, mut y) = svmlight::read_design(&path, P).unwrap();
    assert!(design.is_sparse());
    assert_eq!((design.rows(), design.cols()), (N, P));
    // center y (the solvers assume a centered response)
    let mean = vecops::mean(&y);
    for v in y.iter_mut() {
        *v -= mean;
    }

    // glmnet CD through the sparse Design
    let kappa = 0.5;
    let lambda = glmnet::lambda_max_design(&design, &y, kappa) * 0.2;
    let cfg = GlmnetConfig { kappa, mode: CdMode::Naive, max_epochs: 400, ..Default::default() };
    let cd = glmnet::solve_penalized_design(&design, &y, lambda, &cfg, None);
    let t = vecops::norm1(&cd.beta);
    assert!(t > 0.0, "CD must activate at this lambda");

    // Shotgun through the sparse Design
    let sg = solve_shotgun_design(
        &design,
        &y,
        lambda,
        &ShotgunConfig { kappa, threads: 2, max_epochs: 200, ..Default::default() },
        Some(&cd.beta),
    );
    assert_eq!(sg.beta.len(), P);

    // SVEN (primal Newton over the implicit reduction operator)
    let lambda2 = N as f64 * lambda * (1.0 - kappa);
    let prob = EnProblem::new(design, y, t, lambda2);
    let sven = Sven::new(RustBackend::default());
    let sol = sven.solve(&prob).unwrap();
    assert_eq!(sol.beta.len(), P);
    // the solve is real: budget respected and some support selected
    assert!(vecops::norm1(&sol.beta) <= t * (1.0 + 1e-6));

    let largest = LARGEST.load(Ordering::Relaxed);
    assert!(
        largest < budget,
        "sparse path allocated a {largest}-byte block (budget {budget}; a dense \
         {N}x{P} design would be {dense_bytes}) — something densified"
    );
}
