//! Fault-isolation acceptance tests for the coordinator service:
//! deterministic fault injection ([`FaultPlan`]), per-attempt panic
//! isolation, transient-failure retries, deadline truncation, and
//! cost-based admission control.
//!
//! The invariants pinned here:
//!
//! - An injected solve panic fails *that job* with
//!   `JobError::WorkerPanic`; the worker survives and keeps serving.
//! - Transient faults (panics, failed prep builds) retried under a
//!   `RetryPolicy` converge to results **bit-identical** to a clean run.
//! - A failed preparation build wakes every single-flight waiter with
//!   the failure (no hangs) and evicts the slot so a retry rebuilds.
//! - A deadline that lands mid-sweep returns the solved prefix as
//!   `JobResult::Truncated` — bit-identical to the same prefix of an
//!   unbounded run — for `Path`, `CvPath`, and `MultiResponse` jobs; a
//!   deadline burned entirely in the queue aborts without touching a
//!   solver.
//! - Over-budget submissions shed with `JobError::Overloaded` before
//!   building any state, and the admission budget releases when jobs
//!   finish.
//! - A mixed-traffic soak under a seeded fault schedule at 1/2/8
//!   workers deadlocks never, yields a definite outcome for every job,
//!   and keeps every successful result bit-identical to the clean run
//!   (set `PALLAS_FAULT_SOAK=1` to widen the schedule sweep).
//! - A NaN-poisoned solve trips the numerical-health guardrails: the
//!   job fails with the non-transient `JobError::NumericalBreakdown`
//!   (never retried, never served), and in a multi-response screen the
//!   degradation ladder evicts the sick *member* — its clean prefix is
//!   kept, its siblings finish bit-identical to the clean run.
//! - A sweep killed mid-grid under a retry policy resumes from the
//!   published checkpoint (no prefix re-solve) and still produces the
//!   bit-identical full path; `checkpoints_published` /
//!   `resumed_from_checkpoint` meter the recovery.
//! - A NaN + stall soak (widen with `PALLAS_NAN_SOAK=1`, the CI
//!   `rust-faults` schedule) never serves a non-finite coefficient:
//!   every job ends in a finite success, an exhausted transient, or a
//!   structured breakdown.

use std::sync::Arc;
use std::time::Duration;
use sven::coordinator::{
    BackendChoice, FaultPlan, GridPoint, JobError, JobResult, PoolConfig, RetryPolicy,
    Service, ServiceConfig, SubmitOptions,
};
use sven::data::{synth_regression, Dataset, SynthSpec};
use sven::linalg::Design;

/// Primal-regime dataset (2p > n): the batched sweep machinery engages.
fn primal_data(seed: u64) -> Dataset {
    synth_regression(&SynthSpec { n: 40, p: 48, support: 8, seed, ..Default::default() })
}

/// Dual-regime dataset (2p < n, and still dual on 2-fold training
/// splits): the sequential warm-chained sweep runs point by point.
fn dual_data(seed: u64) -> Dataset {
    synth_regression(&SynthSpec { n: 120, p: 30, support: 6, seed, ..Default::default() })
}

/// A hand-built grid of `k` valid points (t > 0, fixed λ₂).
fn grid(k: usize) -> Vec<GridPoint> {
    (0..k).map(|i| GridPoint { t: 0.2 + 0.05 * i as f64, lambda2: 0.5 }).collect()
}

fn service(workers: usize, config: ServiceConfig) -> Service {
    Service::start(ServiceConfig {
        pool: PoolConfig { workers, queue_capacity: 64 },
        ..config
    })
}

fn assert_bits(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: β length");
    for (j, (va, vb)) in a.iter().zip(b).enumerate() {
        assert_eq!(va.to_bits(), vb.to_bits(), "{ctx}: β bits differ at j={j}");
    }
}

/// An injected solve panic (no retries) fails that job with a
/// structured `WorkerPanic` — and the worker survives to serve the next
/// job on the same thread.
#[test]
fn injected_solve_panic_fails_job_and_worker_survives() {
    let d = primal_data(9001);
    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_panics: vec![0], ..Default::default() }),
            ..Default::default()
        },
    );
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let rx = svc
        .submit_point(1, x.clone(), y.clone(), 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    let err = rx.recv().unwrap().result.unwrap_err();
    match &err {
        JobError::WorkerPanic(msg) => {
            assert!(msg.contains("injected fault"), "panic payload must surface: {msg}")
        }
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    // Same worker, next job: solve ordinal 1 is clean.
    let rx = svc
        .submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    rx.recv().unwrap().result.expect("the worker must survive a caught panic");
    let m = svc.metrics();
    assert_eq!(m.worker_panics(), 1);
    assert_eq!(m.worker_respawns(), 0, "a caught panic must not cost a respawn");
    assert_eq!(m.failed(), 1);
    assert_eq!(m.completed(), 1);
    svc.shutdown();
}

/// A panicking attempt under a retry policy re-runs and succeeds with
/// coefficients bit-identical to a fault-free service.
#[test]
fn transient_panic_retries_to_bit_identical_success() {
    let d = primal_data(9002);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_point(1, x.clone(), y.clone(), 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean solve").expect_point();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_panics: vec![0], ..Default::default() }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions { retry: RetryPolicy::retries(2), ..Default::default() };
    let rx = svc
        .submit_with(
            1,
            x,
            y,
            sven::coordinator::JobKind::Point { t: 0.4, lambda2: 0.5 },
            BackendChoice::Rust,
            opts,
        )
        .expect("accepted");
    let sol = rx.recv().unwrap().result.expect("retried to success").expect_point();
    assert_bits(&clean.beta, &sol.beta, "retried point solve");
    assert_eq!(clean.iterations, sol.iterations, "iteration counts must match too");
    let report = svc.metrics().report();
    assert!(report.contains("worker_panics=1"), "{report}");
    assert!(report.contains("jobs_retried=1"), "{report}");
    svc.shutdown();
}

/// An injected preparation-build failure is transient: the failed slot
/// is evicted, the retry rebuilds it, and the counters record exactly
/// one failure and two builds.
#[test]
fn failed_prep_build_is_evicted_retried_and_counted() {
    let d = primal_data(9003);
    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                prep_build_errors: vec![0],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions { retry: RetryPolicy::retries(2), ..Default::default() };
    let rx = svc
        .submit_with(
            1,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            sven::coordinator::JobKind::Point { t: 0.4, lambda2: 0.5 },
            BackendChoice::Rust,
            opts,
        )
        .expect("accepted");
    rx.recv().unwrap().result.expect("rebuild on retry must succeed");
    let m = svc.metrics();
    assert_eq!(m.prep_build_failures(), 1);
    assert_eq!(m.prep_builds(), 2, "failed build + clean rebuild");
    assert_eq!(m.jobs_retried(), 1);
    assert!(m.report().contains("prep_build_failures=1"));
    svc.shutdown();
}

/// A failing single-flight build must wake every concurrent waiter with
/// the failure (a definite outcome for every job, no hangs), evict the
/// slot, and let later jobs rebuild cleanly.
#[test]
fn prep_build_failure_wakes_concurrent_waiters_without_deadlock() {
    let d = primal_data(9004);
    let svc = service(
        4,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                prep_build_errors: vec![0],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            svc.submit_point(
                1,
                x.clone(),
                y.clone(),
                0.3 + 0.05 * i as f64,
                0.5,
                BackendChoice::Rust,
            )
            .expect("accepted")
        })
        .collect();
    let mut failed = 0usize;
    for rx in rxs {
        let out = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every waiter must get a definite outcome (no hang)");
        match out.result {
            Ok(_) => {}
            Err(JobError::PrepFailed(msg)) => {
                assert!(msg.contains("injected fault"), "{msg}");
                failed += 1;
            }
            Err(other) => panic!("unexpected error kind: {other:?}"),
        }
    }
    assert!(failed >= 1, "at least the build-holding job must see the failure");
    // The failed slot was evicted: a fresh job rebuilds and succeeds.
    let rx = svc
        .submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    rx.recv().unwrap().result.expect("the evicted slot must rebuild cleanly");
    assert_eq!(svc.metrics().prep_build_failures(), 1);
    svc.shutdown();
}

/// A deadline burned entirely in the queue aborts the job before any
/// solver (or preparation) is touched.
#[test]
fn deadline_spent_in_queue_aborts_without_touching_a_solver() {
    let d = primal_data(9005);
    let svc = service(1, ServiceConfig::default());
    let opts = SubmitOptions::with_deadline(Duration::from_nanos(1));
    let rx = svc
        .submit_with(
            1,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            sven::coordinator::JobKind::Point { t: 0.4, lambda2: 0.5 },
            BackendChoice::Rust,
            opts,
        )
        .expect("accepted");
    let err = rx.recv().unwrap().result.unwrap_err();
    assert_eq!(err, JobError::DeadlineExceeded);
    let m = svc.metrics();
    assert_eq!(m.prep_builds(), 0, "an expired job must not build a preparation");
    assert!(m.deadline_aborts() >= 1);
    svc.shutdown();
}

/// A deadline landing mid-sweep on a primal `Path` job (chunk-batched
/// under control) truncates to the solved prefix, bit-identical to the
/// clean run. The injected 1 s stall at solve #0 makes the cut
/// deterministic: the first 8-point chunk completes (the stall sits
/// inside it), the second never starts.
#[test]
fn deadline_truncates_primal_path_to_bit_identical_prefix() {
    let d = primal_data(9006);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let points = grid(12);

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean path").expect_path();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                solve_delays: vec![(0, Duration::from_millis(1000))],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions::with_deadline(Duration::from_millis(300));
    let rx = svc
        .submit_path_with(1, x, y, points.clone(), BackendChoice::Rust, opts)
        .expect("accepted");
    let (completed, total, partial) =
        rx.recv().unwrap().result.expect("a mid-sweep deadline is a success").expect_truncated();
    assert_eq!(total, points.len());
    assert_eq!(completed, 8, "the cut must land at the first chunk boundary");
    let sols = partial.expect_path();
    assert_eq!(sols.len(), completed);
    for (i, (a, b)) in clean.iter().zip(&sols).enumerate() {
        assert_bits(&a.beta, &b.beta, &format!("truncated path point {i}"));
        assert_eq!(a.iterations, b.iterations, "point {i}: iterations");
    }
    let report = svc.metrics().report();
    assert!(report.contains("jobs_truncated=1"), "{report}");
    assert!(svc.metrics().deadline_aborts() >= 1);
    svc.shutdown();
}

/// The same contract in the dual regime, where the sweep is sequential
/// and the deadline is observed at every grid point: the stall at solve
/// #0 cuts the path after exactly one point.
#[test]
fn deadline_truncates_dual_path_to_bit_identical_prefix() {
    let d = dual_data(9007);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let points = grid(6);

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean path").expect_path();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                solve_delays: vec![(0, Duration::from_millis(1000))],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions::with_deadline(Duration::from_millis(300));
    let rx = svc
        .submit_path_with(1, x, y, points.clone(), BackendChoice::Rust, opts)
        .expect("accepted");
    let (completed, total, partial) =
        rx.recv().unwrap().result.expect("truncated success").expect_truncated();
    assert_eq!((completed, total), (1, points.len()));
    let sols = partial.expect_path();
    assert_bits(&clean[0].beta, &sols[0].beta, "dual truncated prefix");
    assert_eq!(clean[0].iterations, sols[0].iterations);
    svc.shutdown();
}

/// A deadline cutting one fold of a `CvPath` job trims every fold to
/// the common solved prefix, scores CV over that prefix, and still
/// refits a winner — with the prefix bit-identical to the clean run's.
#[test]
fn deadline_truncates_cv_path_to_common_bit_identical_prefix() {
    let d = dual_data(9008);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let points = grid(6);
    let folds = 2usize;

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_cv_path(1, x.clone(), y.clone(), folds, points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean cv").expect_cv_path();
    clean_svc.shutdown();

    // Fold 0 consumes solve ordinals 0..6; the stall at ordinal 6 (fold
    // 1, first point) expires the deadline before fold 1's second point.
    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                solve_delays: vec![(6, Duration::from_millis(2000))],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions::with_deadline(Duration::from_millis(600));
    let rx = svc
        .submit_cv_path_with(1, x, y, folds, points.clone(), BackendChoice::Rust, opts)
        .expect("accepted");
    let (completed, total, partial) =
        rx.recv().unwrap().result.expect("truncated success").expect_truncated();
    assert_eq!((completed, total), (1, points.len()));
    let cv = partial.expect_cv_path();
    assert_eq!(cv.fold_paths.len(), folds);
    assert_eq!(cv.cv_errors.len(), completed, "CV scored over the common prefix");
    for f in 0..folds {
        assert_eq!(cv.fold_paths[f].len(), completed, "fold {f} trimmed to the prefix");
        assert_bits(
            &clean.fold_paths[f][0].beta,
            &cv.fold_paths[f][0].beta,
            &format!("cv fold {f} prefix"),
        );
    }
    assert!(cv.best_index < completed);
    svc.shutdown();
}

/// A deadline cutting a `MultiResponse` sweep trims every response to
/// the common grid prefix — bit-identical to the clean screen's prefix.
#[test]
fn deadline_truncates_multi_response_to_common_bit_identical_prefix() {
    let d = primal_data(9009);
    let x = Arc::new(Design::from(d.x.clone()));
    let responses: Vec<Arc<Vec<f64>>> = (0..3)
        .map(|i| {
            let f = 0.7 + 0.3 * i as f64;
            Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
        })
        .collect();
    let points = grid(6);

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_multi_response(1, x.clone(), responses.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean screen").expect_multi_response();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan {
                solve_delays: vec![(0, Duration::from_millis(1000))],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions::with_deadline(Duration::from_millis(300));
    let rx = svc
        .submit_multi_response_with(1, x, responses, points.clone(), BackendChoice::Rust, opts)
        .expect("accepted");
    let (completed, total, partial) =
        rx.recv().unwrap().result.expect("truncated success").expect_truncated();
    assert_eq!((completed, total), (1, points.len()));
    let res = partial.expect_multi_response();
    assert_eq!(res.paths.len(), 3);
    for (r, path) in res.paths.iter().enumerate() {
        assert_eq!(path.len(), completed, "response {r} trimmed to the common prefix");
        assert_bits(
            &clean.paths[r][0].beta,
            &path[0].beta,
            &format!("screen response {r} prefix"),
        );
        assert_eq!(res.early_stopped_at[r], None);
    }
    assert_eq!(res.lambda_max.len(), 3);
    svc.shutdown();
}

/// An over-budget submission sheds synchronously with the depth facts in
/// the error — before an id, a channel, a validation pass, or a
/// preparation exists.
#[test]
fn over_budget_submission_sheds_before_any_state() {
    let d = primal_data(9010);
    let svc = service(
        1,
        ServiceConfig { max_queue_depth: Some(4), ..Default::default() },
    );
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let err = svc
        .submit_path(1, x.clone(), y.clone(), grid(6), BackendChoice::Rust)
        .unwrap_err();
    assert_eq!(err, JobError::Overloaded { depth: 0, max_depth: 4, cost: 6 });
    let m = svc.metrics();
    assert_eq!(m.jobs_shed(), 1);
    assert_eq!(m.submitted(), 0, "a shed job must not count as submitted");
    assert_eq!(m.prep_builds(), 0, "a shed job must touch no worker");
    assert!(m.report().contains("jobs_shed=1"));
    // A job within budget still flows.
    let rx = svc
        .submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust)
        .expect("cost-1 job fits the budget");
    rx.recv().unwrap().result.expect("solve ok");
    svc.shutdown();
}

/// The admission charge is held for the job's whole lifetime (shedding
/// concurrent work at full depth) and released when it finishes.
#[test]
fn admission_budget_releases_when_the_job_finishes() {
    let d = primal_data(9011);
    let svc = service(
        1,
        ServiceConfig {
            max_queue_depth: Some(6),
            // Stall the first solve so the budget is provably still held
            // when the second submission arrives.
            fault_plan: Some(FaultPlan {
                solve_delays: vec![(0, Duration::from_millis(300))],
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let points = grid(6);
    let rx = svc
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("first path fills the budget exactly");
    assert_eq!(svc.admitted_depth(), 6);
    let err = svc
        .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .unwrap_err();
    assert_eq!(err, JobError::Overloaded { depth: 6, max_depth: 6, cost: 6 });
    rx.recv().unwrap().result.expect("held job completes");
    // The ticket drops with the job's state just after the reply lands.
    let mut waited = 0;
    while svc.admitted_depth() > 0 && waited < 100 {
        std::thread::sleep(Duration::from_millis(10));
        waited += 1;
    }
    assert_eq!(svc.admitted_depth(), 0, "the budget must release on completion");
    let rx = svc
        .submit_path(1, x, y, points, BackendChoice::Rust)
        .expect("released budget admits the next job");
    rx.recv().unwrap().result.expect("solve ok");
    svc.shutdown();
}

/// Clean-run reference results for the soak: one point per grid entry,
/// a primal path, a CV path, a multi-response screen, and a dual path.
struct SoakRef {
    points: Vec<Vec<f64>>,
    path: Vec<Vec<f64>>,
    cv_folds: Vec<Vec<Vec<f64>>>,
    multi: Vec<Vec<Vec<f64>>>,
    dual_path: Vec<Vec<f64>>,
}

fn betas(sols: &[sven::solvers::elastic_net::EnSolution]) -> Vec<Vec<f64>> {
    sols.iter().map(|s| s.beta.clone()).collect()
}

/// Mixed traffic (Point / Path / CvPath / MultiResponse, both SVM
/// regimes) under a seeded fault schedule at 1, 2, and 8 workers: no
/// deadlock, a definite outcome for every job, only transient error
/// kinds on the jobs the schedule managed to kill, and bit-identity for
/// everything that succeeded. `PALLAS_FAULT_SOAK=1` widens the seed
/// sweep.
#[test]
fn mixed_traffic_soak_under_seeded_faults() {
    let d = primal_data(9012);
    let dd = dual_data(9013);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let xd = Arc::new(Design::from(dd.x.clone()));
    let yd = Arc::new(dd.y.clone());
    let points = grid(6);
    let responses: Vec<Arc<Vec<f64>>> = (0..3)
        .map(|i| {
            let f = 0.7 + 0.3 * i as f64;
            Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
        })
        .collect();

    // Clean reference, once, on a single worker.
    let clean = service(1, ServiceConfig::default());
    let reference = SoakRef {
        points: points
            .iter()
            .map(|gp| {
                let rx = clean
                    .submit_point(1, x.clone(), y.clone(), gp.t, gp.lambda2, BackendChoice::Rust)
                    .expect("accepted");
                rx.recv().unwrap().result.expect("clean point").expect_point().beta
            })
            .collect(),
        path: betas(
            &clean
                .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
                .expect("accepted")
                .recv()
                .unwrap()
                .result
                .expect("clean path")
                .expect_path(),
        ),
        cv_folds: clean
            .submit_cv_path(1, x.clone(), y.clone(), 2, points.clone(), BackendChoice::Rust)
            .expect("accepted")
            .recv()
            .unwrap()
            .result
            .expect("clean cv")
            .expect_cv_path()
            .fold_paths
            .iter()
            .map(|p| betas(p))
            .collect(),
        multi: clean
            .submit_multi_response(1, x.clone(), responses.clone(), points.clone(), BackendChoice::Rust)
            .expect("accepted")
            .recv()
            .unwrap()
            .result
            .expect("clean screen")
            .expect_multi_response()
            .paths
            .iter()
            .map(|p| betas(p))
            .collect(),
        dual_path: betas(
            &clean
                .submit_path(2, xd.clone(), yd.clone(), points.clone(), BackendChoice::Rust)
                .expect("accepted")
                .recv()
                .unwrap()
                .result
                .expect("clean dual path")
                .expect_path(),
        ),
    };
    clean.shutdown();

    let seeds: &[u64] = if std::env::var("PALLAS_FAULT_SOAK").is_ok() {
        &[11, 12, 13]
    } else {
        &[11]
    };
    for &seed in seeds {
        for &workers in &[1usize, 2, 8] {
            // Seeded schedule plus a guaranteed early solve panic, so
            // every run provably exercises the recovery path.
            let mut plan = FaultPlan::seeded(seed, 48, 4);
            plan.solve_panics.push(1);
            plan.solve_panics.sort_unstable();
            plan.solve_panics.dedup();
            let svc = service(
                workers,
                ServiceConfig { fault_plan: Some(plan), ..Default::default() },
            );
            let opts = SubmitOptions { retry: RetryPolicy::retries(4), ..Default::default() };
            let mut jobs: Vec<(String, std::sync::mpsc::Receiver<_>)> = Vec::new();
            for (i, gp) in points.iter().enumerate().take(4) {
                let rx = svc
                    .submit_with(
                        1,
                        x.clone(),
                        y.clone(),
                        sven::coordinator::JobKind::Point { t: gp.t, lambda2: gp.lambda2 },
                        BackendChoice::Rust,
                        opts,
                    )
                    .expect("accepted");
                jobs.push((format!("point{i}"), rx));
            }
            jobs.push((
                "path".into(),
                svc.submit_path_with(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust, opts)
                    .expect("accepted"),
            ));
            jobs.push((
                "cv".into(),
                svc.submit_cv_path_with(1, x.clone(), y.clone(), 2, points.clone(), BackendChoice::Rust, opts)
                    .expect("accepted"),
            ));
            jobs.push((
                "multi".into(),
                svc.submit_multi_response_with(
                    1,
                    x.clone(),
                    responses.clone(),
                    points.clone(),
                    BackendChoice::Rust,
                    opts,
                )
                .expect("accepted"),
            ));
            jobs.push((
                "dual_path".into(),
                svc.submit_path_with(2, xd.clone(), yd.clone(), points.clone(), BackendChoice::Rust, opts)
                    .expect("accepted"),
            ));
            for (name, rx) in jobs {
                let ctx = format!("seed {seed}, {workers} workers, job {name}");
                let out = rx
                    .recv_timeout(Duration::from_secs(60))
                    .unwrap_or_else(|e| panic!("{ctx}: no definite outcome ({e})"));
                match out.result {
                    Ok(JobResult::Truncated { .. }) => {
                        panic!("{ctx}: no deadline was set, truncation is a bug")
                    }
                    Ok(JobResult::Point(sol)) => {
                        let i: usize = name["point".len()..].parse().unwrap();
                        assert_bits(&reference.points[i], &sol.beta, &ctx);
                    }
                    Ok(JobResult::Path(sols)) => {
                        let want = if name == "path" { &reference.path } else { &reference.dual_path };
                        assert_eq!(sols.len(), want.len(), "{ctx}");
                        for (i, s) in sols.iter().enumerate() {
                            assert_bits(&want[i], &s.beta, &format!("{ctx} pt {i}"));
                        }
                    }
                    Ok(JobResult::CvPath(cv)) => {
                        for (f, path) in cv.fold_paths.iter().enumerate() {
                            assert_eq!(path.len(), points.len(), "{ctx}");
                            for (i, s) in path.iter().enumerate() {
                                assert_bits(
                                    &reference.cv_folds[f][i],
                                    &s.beta,
                                    &format!("{ctx} fold {f} pt {i}"),
                                );
                            }
                        }
                    }
                    Ok(JobResult::MultiResponse(res)) => {
                        for (r, path) in res.paths.iter().enumerate() {
                            assert_eq!(path.len(), points.len(), "{ctx}");
                            for (i, s) in path.iter().enumerate() {
                                assert_bits(
                                    &reference.multi[r][i],
                                    &s.beta,
                                    &format!("{ctx} resp {r} pt {i}"),
                                );
                            }
                        }
                    }
                    Err(e) => {
                        assert!(
                            e.is_transient(),
                            "{ctx}: only exhausted transient faults may fail a job, got {e:?}"
                        );
                    }
                }
            }
            let m = svc.metrics();
            assert!(
                m.worker_panics() >= 1,
                "the pinned solve panic must have fired (seed {seed}, {workers} workers)"
            );
            let report = m.report();
            for key in ["worker_panics=", "worker_respawns=", "jobs_retried=", "jobs_shed="] {
                assert!(report.contains(key), "metric {key} missing from report: {report}");
            }
            svc.shutdown();
        }
    }
}

/// A NaN-poisoned point solve is caught by the numerical-health
/// guardrails and fails with the structured, non-transient
/// `NumericalBreakdown` — the half-broken iterate is never served, and
/// the worker survives to serve the next (clean) job finitely.
#[test]
fn nan_poisoned_point_fails_with_numerical_breakdown() {
    let d = primal_data(9014);
    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_nans: vec![0], ..Default::default() }),
            ..Default::default()
        },
    );
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let rx = svc
        .submit_point(1, x.clone(), y.clone(), 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    let err = rx.recv().unwrap().result.unwrap_err();
    match &err {
        JobError::NumericalBreakdown { stage, detail } => {
            assert!(!stage.is_empty(), "the tripped guard must be named");
            assert!(!detail.is_empty(), "the breakdown detail must survive: {stage}");
        }
        other => panic!("expected NumericalBreakdown, got {other:?}"),
    }
    assert!(!err.is_transient(), "breakdowns are deterministic; retrying cannot heal them");
    // Ordinal 1 is clean: the worker outlives the breakdown and serves
    // finite coefficients.
    let rx = svc
        .submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust)
        .expect("accepted");
    let sol = rx.recv().unwrap().result.expect("clean ordinal succeeds").expect_point();
    assert!(sol.beta.iter().all(|v| v.is_finite()), "a served β must be finite");
    let m = svc.metrics();
    assert!(m.numerical_breakdowns() >= 1);
    assert_eq!(m.failed(), 1);
    assert_eq!(m.completed(), 1);
    assert!(m.report().contains("numerical_breakdowns="), "{}", m.report());
    svc.shutdown();
}

/// A retry policy must not burn attempts on a breakdown: the fault is in
/// the job's arithmetic, not its execution, so the first breakdown is
/// final.
#[test]
fn numerical_breakdown_is_never_retried() {
    let d = primal_data(9015);
    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_nans: vec![0], ..Default::default() }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions { retry: RetryPolicy::retries(3), ..Default::default() };
    let rx = svc
        .submit_with(
            1,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            sven::coordinator::JobKind::Point { t: 0.4, lambda2: 0.5 },
            BackendChoice::Rust,
            opts,
        )
        .expect("accepted");
    let err = rx.recv().unwrap().result.unwrap_err();
    assert!(matches!(err, JobError::NumericalBreakdown { .. }), "{err:?}");
    let m = svc.metrics();
    assert_eq!(m.jobs_retried(), 0, "a deterministic breakdown must fail on attempt one");
    svc.shutdown();
}

/// The degradation ladder fails the *member*, not the batch: a
/// NaN-poisoned response in a multi-response screen is evicted with its
/// clean prefix intact, the verdict names it in `broken`, and its
/// siblings finish the full grid bit-identical to a fault-free run.
///
/// Ordinal math: with every response live, the point-major sweep draws
/// one poison verdict per member per point — point 0 consumes ordinals
/// 0,1,2 and point 1 consumes 3,4,5 — so poisoning ordinal 4 hits
/// member 1 at grid point 1, leaving it a one-point clean prefix.
#[test]
fn nan_poisoned_member_is_evicted_and_siblings_stay_bit_identical() {
    let d = primal_data(9016);
    let x = Arc::new(Design::from(d.x.clone()));
    let responses: Vec<Arc<Vec<f64>>> = (0..3)
        .map(|i| {
            let f = 0.7 + 0.3 * i as f64;
            Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
        })
        .collect();
    let points = grid(6);

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_multi_response(1, x.clone(), responses.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean screen").expect_multi_response();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_nans: vec![4], ..Default::default() }),
            ..Default::default()
        },
    );
    let rx = svc
        .submit_multi_response(1, x, responses, points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let res = rx
        .recv()
        .unwrap()
        .result
        .expect("an evicted member must not fail the batch")
        .expect_multi_response();
    assert!(res.broken[1].is_some(), "member 1 must carry the breakdown verdict");
    assert!(res.broken[0].is_none() && res.broken[2].is_none());
    assert_eq!(res.paths[1].len(), 1, "the sick member keeps exactly its clean prefix");
    assert_bits(&clean.paths[1][0].beta, &res.paths[1][0].beta, "sick member prefix");
    for r in [0usize, 2] {
        assert_eq!(res.paths[r].len(), points.len(), "sibling {r} must finish the grid");
        for (i, (a, b)) in clean.paths[r].iter().zip(&res.paths[r]).enumerate() {
            assert_bits(&a.beta, &b.beta, &format!("sibling {r} pt {i}"));
        }
    }
    for path in &res.paths {
        for sol in path {
            assert!(
                sol.beta.iter().all(|v| v.is_finite()),
                "no served β may carry the injected NaN"
            );
        }
    }
    let m = svc.metrics();
    assert_eq!(m.members_evicted(), 1);
    let report = m.report();
    assert!(report.contains("members_evicted=1"), "{report}");
    svc.shutdown();
}

/// A sweep killed mid-grid under a retry policy resumes from the
/// published checkpoint: the solved prefix is not re-solved, and the
/// assembled path is bit-for-bit what an uninterrupted run produces.
///
/// The dual-regime sweep draws one fault ordinal per grid point, so a
/// panic at ordinal 3 kills the first attempt after checkpointing three
/// points; the retry resumes at point 3 (consuming ordinals 4..) and
/// publishes exactly the three remaining points.
#[test]
fn killed_sweep_resumes_from_checkpoint_bit_identical() {
    let d = dual_data(9017);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let points = grid(6);

    let clean_svc = service(1, ServiceConfig::default());
    let rx = clean_svc
        .submit_path(2, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
        .expect("accepted");
    let clean = rx.recv().unwrap().result.expect("clean path").expect_path();
    clean_svc.shutdown();

    let svc = service(
        1,
        ServiceConfig {
            fault_plan: Some(FaultPlan { solve_panics: vec![3], ..Default::default() }),
            ..Default::default()
        },
    );
    let opts = SubmitOptions { retry: RetryPolicy::retries(2), ..Default::default() };
    let rx = svc
        .submit_path_with(2, x, y, points.clone(), BackendChoice::Rust, opts)
        .expect("accepted");
    let sols = rx.recv().unwrap().result.expect("retried to success").expect_path();
    assert_eq!(sols.len(), points.len());
    for (i, (a, b)) in clean.iter().zip(&sols).enumerate() {
        assert_bits(&a.beta, &b.beta, &format!("resumed path pt {i}"));
        assert_eq!(a.iterations, b.iterations, "pt {i}: iterations");
    }
    let m = svc.metrics();
    assert_eq!(m.worker_panics(), 1);
    assert_eq!(m.jobs_retried(), 1);
    assert_eq!(
        m.resumed_from_checkpoint(),
        1,
        "the retry must resume, not re-solve from scratch"
    );
    assert_eq!(
        m.checkpoints_published(),
        3,
        "only the points the resumed attempt newly finished are metered"
    );
    let report = m.report();
    for key in ["checkpoints_published=3", "resumed_from_checkpoint=1"] {
        assert!(report.contains(key), "metric {key} missing from report: {report}");
    }
    svc.shutdown();
}

/// The CI `rust-faults` schedule: seeded NaN poisoning *and* stalls on
/// top of the transient plan. Every job must end in a finite success, an
/// exhausted transient, or a structured breakdown — an injected
/// non-finite value must never reach a served β. `PALLAS_NAN_SOAK=1`
/// widens the seed sweep.
#[test]
fn nan_and_stall_soak_never_serves_non_finite() {
    let d = primal_data(9018);
    let dd = dual_data(9019);
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let xd = Arc::new(Design::from(dd.x.clone()));
    let yd = Arc::new(dd.y.clone());
    let points = grid(6);
    let responses: Vec<Arc<Vec<f64>>> = (0..3)
        .map(|i| {
            let f = 0.7 + 0.3 * i as f64;
            Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
        })
        .collect();
    let seeds: &[u64] = if std::env::var("PALLAS_NAN_SOAK").is_ok() {
        &[21, 22, 23]
    } else {
        &[21]
    };
    let assert_finite = |sols: &[sven::solvers::elastic_net::EnSolution], ctx: &str| {
        for (i, s) in sols.iter().enumerate() {
            assert!(
                s.beta.iter().all(|v| v.is_finite()),
                "{ctx}: non-finite β served at pt {i}"
            );
        }
    };
    for &seed in seeds {
        for &workers in &[1usize, 2, 8] {
            let plan = FaultPlan::seeded(seed, 48, 2).with_seeded_nans(seed, 48, 4);
            assert!(!plan.solve_nans.is_empty(), "the NaN schedule must inject");
            let svc = service(
                workers,
                ServiceConfig { fault_plan: Some(plan), ..Default::default() },
            );
            let opts = SubmitOptions { retry: RetryPolicy::retries(4), ..Default::default() };
            let mut jobs: Vec<(String, std::sync::mpsc::Receiver<_>)> = Vec::new();
            for (i, gp) in points.iter().enumerate().take(3) {
                let rx = svc
                    .submit_with(
                        1,
                        x.clone(),
                        y.clone(),
                        sven::coordinator::JobKind::Point { t: gp.t, lambda2: gp.lambda2 },
                        BackendChoice::Rust,
                        opts,
                    )
                    .expect("accepted");
                jobs.push((format!("point{i}"), rx));
            }
            jobs.push((
                "path".into(),
                svc.submit_path_with(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust, opts)
                    .expect("accepted"),
            ));
            jobs.push((
                "dual_path".into(),
                svc.submit_path_with(2, xd.clone(), yd.clone(), points.clone(), BackendChoice::Rust, opts)
                    .expect("accepted"),
            ));
            jobs.push((
                "multi".into(),
                svc.submit_multi_response_with(
                    1,
                    x.clone(),
                    responses.clone(),
                    points.clone(),
                    BackendChoice::Rust,
                    opts,
                )
                .expect("accepted"),
            ));
            for (name, rx) in jobs {
                let ctx = format!("nan soak seed {seed}, {workers} workers, job {name}");
                let out = rx
                    .recv_timeout(Duration::from_secs(60))
                    .unwrap_or_else(|e| panic!("{ctx}: no definite outcome ({e})"));
                match out.result {
                    Ok(JobResult::Point(sol)) => assert_finite(std::slice::from_ref(&sol), &ctx),
                    Ok(JobResult::Path(sols)) => assert_finite(&sols, &ctx),
                    Ok(JobResult::MultiResponse(res)) => {
                        for (r, path) in res.paths.iter().enumerate() {
                            assert_finite(path, &format!("{ctx} resp {r}"));
                            if path.len() < points.len() {
                                assert!(
                                    res.broken[r].is_some(),
                                    "{ctx}: only an evicted member may stop short"
                                );
                            }
                        }
                    }
                    Ok(other) => panic!("{ctx}: unexpected result shape {other:?}"),
                    Err(e) => assert!(
                        e.is_transient() || matches!(e, JobError::NumericalBreakdown { .. }),
                        "{ctx}: only exhausted transients or breakdowns may fail, got {e:?}"
                    ),
                }
            }
            let report = svc.metrics().report();
            for key in ["numerical_breakdowns=", "members_evicted=", "checkpoints_published="] {
                assert!(report.contains(key), "metric {key} missing from report: {report}");
            }
            svc.shutdown();
        }
    }
}
