//! Peak-memory seal on the in-band blocked Gram kernel.
//!
//! An earlier `blocked_gram_into` staged every upper-triangle block pair
//! in its own buffer before a scatter/mirror pass — ~m²/2 transient
//! doubles (16.8 MB at m = 2048) on top of G itself. The band-writing
//! kernel computes blocks straight into their destination rows and
//! mirrors through a `split_at_mut` frontier, so its transient footprint
//! is one packed A tile + one packed Aᵀ panel per worker (≲ 0.5 MB each
//! under any plausible cache-derived `bs`/`kc`). A live-byte-tracking
//! allocator pins the difference: the extra peak during the call must
//! stay far under the staged scheme's block storage.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use sven::linalg::KernelCtx;

/// Tracks live heap bytes and their high-water mark.
struct PeakTrackingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn on_grow(bytes: usize) {
    let live = LIVE.fetch_add(bytes, Ordering::Relaxed) + bytes;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for PeakTrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            on_grow(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                on_grow(new_size - layout.size());
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: PeakTrackingAlloc = PeakTrackingAlloc;

/// Reference `G = A·Aᵀ` by plain loops, writing into a preallocated
/// buffer (the crate's naive kernel is no longer public — and this test
/// must not allocate inside the tracked window anyway).
fn naive_gram(a: &[f64], g: &mut [f64], m: usize, k: usize) {
    for i in 0..m {
        for j in 0..m {
            let mut s = 0.0;
            for kk in 0..k {
                s += a[i * k + kk] * a[j * k + kk];
            }
            g[i * m + j] = s;
        }
    }
}

/// One test fn so no concurrent test pollutes the high-water mark.
#[test]
fn blocked_gram_has_no_quadratic_transients() {
    // m spans many gram bands under any derived `bs`; k kept small so
    // the debug-mode flop count stays cheap — the assertion is about
    // allocation, not speed.
    const M: usize = 2048;
    const K: usize = 32;
    let staged_bytes = M * M / 2 * std::mem::size_of::<f64>(); // ~16.8 MB
    // Budget: half the staged scheme's block storage. The in-band kernel
    // needs one packed tile + one packed panel per worker — ≲ 0.5 MB
    // each even at the largest cache-derived bs/kc, so ~4 MB at 4
    // workers with allocator slop. That passes with a wide margin while
    // any regression back to staged block pairs trips the budget.
    let budget = staged_bytes / 2;

    // Setup (untracked): inputs, outputs, and the kernel context —
    // resolving it probes cache geometry, which may allocate — all land
    // before the reset.
    let ctx = *KernelCtx::current();
    let mut rng = sven::rng::Rng::seed_from(4141);
    let a: Vec<f64> = (0..M * K).map(|_| rng.normal()).collect();
    let mut g = vec![0.0f64; M * M];
    let mut reference = vec![0.0f64; M * M];
    naive_gram(&a, &mut reference, M, K);

    let baseline = LIVE.load(Ordering::Relaxed);
    PEAK.store(baseline, Ordering::Relaxed);
    ctx.blocked_gram_into(&a, &mut g, M, K, 4);
    let extra = PEAK.load(Ordering::Relaxed).saturating_sub(baseline);

    assert!(
        extra < budget,
        "blocked_gram_into peaked {extra} transient bytes (budget {budget}, staged \
         scheme would need >= {staged_bytes}) — block buffers are back"
    );
    // And the in-band kernel still computes the right gram.
    let dev = g
        .iter()
        .zip(&reference)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(dev < 1e-10, "gram deviation {dev}");
}
