//! Coordinator-service acceptance tests for the zero-copy / shared-prep
//! redesign:
//!
//! - K concurrent jobs on one data set build the preparation exactly
//!   once (single-flight), asserted through the metrics counters.
//! - Sparse and dense designs of the same problem agree through the
//!   service.
//! - A `JobKind::Path` service job reproduces an offline
//!   `PathRunner::run` **bit-for-bit** (shared `sweep_prepared` core).
//! - Closed services reject submissions with `ServiceClosed` instead of
//!   silently dropping them.

use std::sync::Arc;
use sven::coordinator::{
    BackendChoice, PathRunner, PathRunnerConfig, PoolConfig, Service, ServiceConfig,
};
use sven::data::{synth_regression, SynthSpec};
use sven::linalg::{Csr, Design};
use sven::solvers::sven::{RustBackend, Sven};

/// K jobs, one data set, several workers racing on a cold cache: exactly
/// one preparation build, shared by everyone — the amortization invariant
/// the whole redesign exists for.
#[test]
fn concurrent_same_dataset_jobs_build_prep_once() {
    // Dual regime with a non-trivial gram so the build takes long enough
    // for the workers to actually race into the single-flight path.
    let d = synth_regression(&SynthSpec {
        n: 600,
        p: 60,
        support: 10,
        seed: 801,
        ..Default::default()
    });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 4, queue_capacity: 32 },
        ..Default::default()
    });
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let jobs = 12usize;
    let rxs: Vec<_> = (0..jobs)
        .map(|i| {
            service
                .submit_point(
                    42,
                    x.clone(),
                    y.clone(),
                    0.3 + 0.05 * i as f64,
                    0.5,
                    BackendChoice::Rust,
                )
                .expect("service accepting jobs")
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().result.expect("solve ok");
    }
    let m = service.metrics();
    assert_eq!(m.prep_builds(), 1, "single-flight must dedup the builds");
    assert_eq!(m.prep_hits(), jobs as u64 - 1);
    assert_eq!(m.prep_evictions(), 0);
    assert_eq!(service.prep_cache_len(), 1);
    assert_eq!(m.completed(), jobs as u64);
    // Queue-wait metrics are live now: with 12 jobs on 4 workers some job
    // waited a measurable, non-negative time, and the summary exists.
    let qw = m.queue_wait_summary().expect("queue waits recorded");
    assert!(qw.max() >= 0.0);
    assert_eq!(qw.n(), jobs);
    service.shutdown();
}

/// The same synthetic problem served through a dense and a sparse
/// `Design` must agree — the never-densify path composes with the shared
/// prep cache (distinct dataset ids ⇒ two builds, no cross-talk).
#[test]
fn sparse_and_dense_service_jobs_agree() {
    let mut rng = sven::rng::Rng::seed_from(802);
    let dense_mat = sven::linalg::Mat::from_fn(80, 120, |_, _| {
        if rng.bernoulli(0.15) {
            rng.normal()
        } else {
            0.0
        }
    });
    let y: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 2, queue_capacity: 8 },
        ..Default::default()
    });
    let x_dense = Arc::new(Design::from(dense_mat.clone()));
    let x_sparse = Arc::new(Design::from(Csr::from_dense(&dense_mat, 0.0)));
    assert!(x_sparse.is_sparse());
    let y = Arc::new(y);
    let (t, lambda2) = (0.8, 0.5);
    let rx_dense = service
        .submit_point(1, x_dense, y.clone(), t, lambda2, BackendChoice::Rust)
        .unwrap();
    let rx_sparse = service
        .submit_point(2, x_sparse, y.clone(), t, lambda2, BackendChoice::Rust)
        .unwrap();
    let beta_dense = rx_dense.recv().unwrap().result.expect("dense ok").expect_point().beta;
    let beta_sparse =
        rx_sparse.recv().unwrap().result.expect("sparse ok").expect_point().beta;
    assert_eq!(beta_dense.len(), 120);
    for j in 0..120 {
        assert!(
            (beta_dense[j] - beta_sparse[j]).abs() < 1e-5,
            "j={j}: dense {} vs sparse {}",
            beta_dense[j],
            beta_sparse[j]
        );
    }
    assert_eq!(service.metrics().prep_builds(), 2, "two datasets, two builds");
    service.shutdown();
}

/// A path submitted as one `JobKind::Path` job must reproduce the
/// offline `PathRunner::run` coefficient sequence bit-for-bit: both run
/// the same `sweep_prepared` chaining over the same preparation kind.
#[test]
fn path_job_matches_offline_runner_bit_for_bit() {
    for (n, p, seed) in [(40usize, 60usize, 803u64), (150, 12, 804)] {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p / 2),
            seed,
            ..Default::default()
        });
        let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
        let grid = runner.derive_grid(&d);
        assert!(!grid.is_empty());

        // offline: prepared reuse + warm starts inside PathRunner::run
        let sven_solver = Sven::new(RustBackend::default());
        let offline = runner.run(&d, &sven_solver, &grid).unwrap();

        // service: the same grid as one path job
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 4 },
            ..Default::default()
        });
        let rx = service
            .submit_path(
                9,
                Arc::new(Design::from(d.x.clone())),
                Arc::new(d.y.clone()),
                runner.grid_points(&grid),
                BackendChoice::Rust,
            )
            .unwrap();
        let served = rx.recv().unwrap().result.expect("path ok").expect_path();
        service.shutdown();

        assert_eq!(served.len(), offline.len());
        for (i, (off, srv)) in offline.iter().zip(&served).enumerate() {
            assert_eq!(off.beta.len(), srv.beta.len());
            for j in 0..off.beta.len() {
                assert_eq!(
                    off.beta[j].to_bits(),
                    srv.beta[j].to_bits(),
                    "{n}x{p} point {i} j={j}: offline {} vs served {}",
                    off.beta[j],
                    srv.beta[j]
                );
            }
            assert_eq!(off.iterations, srv.iterations, "{n}x{p} point {i}");
        }
    }
}

/// Submissions after `close()` come back as `Err(ServiceClosed)` — the
/// caller can tell "queued" from "rejected".
#[test]
fn closed_service_rejects_submissions() {
    let d = synth_regression(&SynthSpec {
        n: 20,
        p: 10,
        support: 4,
        seed: 805,
        ..Default::default()
    });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 1, queue_capacity: 4 },
        ..Default::default()
    });
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    // accepted while open
    let rx = service
        .submit_point(1, x.clone(), y.clone(), 0.4, 0.5, BackendChoice::Rust)
        .expect("open service accepts");
    rx.recv().unwrap().result.expect("solve ok");
    service.close();
    let rejected = service.submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust);
    assert!(rejected.is_err(), "closed service must reject");
    assert_eq!(service.metrics().rejected(), 1);
    assert_eq!(service.metrics().submitted(), 1);
    service.shutdown();
}
