//! Coordinator-service acceptance tests for the zero-copy / shared-prep
//! redesign:
//!
//! - K concurrent jobs on one data set build the preparation exactly
//!   once (single-flight), asserted through the metrics counters.
//! - Sparse and dense designs of the same problem agree through the
//!   service.
//! - A `JobKind::Path` service job reproduces an offline
//!   `PathRunner::run` **bit-for-bit** (shared `sweep_prepared` core).
//! - A `JobKind::CvPath` job reproduces k standalone fold `Path` jobs
//!   **bit-for-bit** while building exactly one preparation per fold
//!   (plus the winning refit), and the batched-Newton fusion stats flow
//!   through `sweep_prepared` into the metrics.
//! - Closed services reject submissions with `JobError::Closed` instead
//!   of silently dropping them.

use std::sync::Arc;
use sven::coordinator::cv::fold_problem;
use sven::coordinator::path::sweep_prepared;
use sven::coordinator::{
    BackendChoice, GridPoint, PathRunner, PathRunnerConfig, PoolConfig, Service,
    ServiceConfig,
};
use sven::data::{synth_regression, SynthSpec};
use sven::linalg::{Csr, Design};
use sven::solvers::sven::{RustBackend, Sven, SvmScratch};

/// K jobs, one data set, several workers racing on a cold cache: exactly
/// one preparation build, shared by everyone — the amortization invariant
/// the whole redesign exists for.
#[test]
fn concurrent_same_dataset_jobs_build_prep_once() {
    // Dual regime with a non-trivial gram so the build takes long enough
    // for the workers to actually race into the single-flight path.
    let d = synth_regression(&SynthSpec {
        n: 600,
        p: 60,
        support: 10,
        seed: 801,
        ..Default::default()
    });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 4, queue_capacity: 32 },
        ..Default::default()
    });
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let jobs = 12usize;
    let rxs: Vec<_> = (0..jobs)
        .map(|i| {
            service
                .submit_point(
                    42,
                    x.clone(),
                    y.clone(),
                    0.3 + 0.05 * i as f64,
                    0.5,
                    BackendChoice::Rust,
                )
                .expect("service accepting jobs")
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().result.expect("solve ok");
    }
    let m = service.metrics();
    assert_eq!(m.prep_builds(), 1, "single-flight must dedup the builds");
    assert_eq!(m.prep_hits(), jobs as u64 - 1);
    assert_eq!(m.prep_evictions(), 0);
    assert_eq!(service.prep_cache_len(), 1);
    assert_eq!(m.completed(), jobs as u64);
    // Queue-wait metrics are live now: with 12 jobs on 4 workers some job
    // waited a measurable, non-negative time, and the summary exists.
    let qw = m.queue_wait_summary().expect("queue waits recorded");
    assert!(qw.max() >= 0.0);
    assert_eq!(qw.n(), jobs);
    service.shutdown();
}

/// The same synthetic problem served through a dense and a sparse
/// `Design` must agree — the never-densify path composes with the shared
/// prep cache (distinct dataset ids ⇒ two builds, no cross-talk).
#[test]
fn sparse_and_dense_service_jobs_agree() {
    let mut rng = sven::rng::Rng::seed_from(802);
    let dense_mat = sven::linalg::Mat::from_fn(80, 120, |_, _| {
        if rng.bernoulli(0.15) {
            rng.normal()
        } else {
            0.0
        }
    });
    let y: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 2, queue_capacity: 8 },
        ..Default::default()
    });
    let x_dense = Arc::new(Design::from(dense_mat.clone()));
    let x_sparse = Arc::new(Design::from(Csr::from_dense(&dense_mat, 0.0)));
    assert!(x_sparse.is_sparse());
    let y = Arc::new(y);
    let (t, lambda2) = (0.8, 0.5);
    let rx_dense = service
        .submit_point(1, x_dense, y.clone(), t, lambda2, BackendChoice::Rust)
        .unwrap();
    let rx_sparse = service
        .submit_point(2, x_sparse, y.clone(), t, lambda2, BackendChoice::Rust)
        .unwrap();
    let beta_dense = rx_dense.recv().unwrap().result.expect("dense ok").expect_point().beta;
    let beta_sparse =
        rx_sparse.recv().unwrap().result.expect("sparse ok").expect_point().beta;
    assert_eq!(beta_dense.len(), 120);
    for j in 0..120 {
        assert!(
            (beta_dense[j] - beta_sparse[j]).abs() < 1e-5,
            "j={j}: dense {} vs sparse {}",
            beta_dense[j],
            beta_sparse[j]
        );
    }
    assert_eq!(service.metrics().prep_builds(), 2, "two datasets, two builds");
    service.shutdown();
}

/// A path submitted as one `JobKind::Path` job must reproduce the
/// offline `PathRunner::run` coefficient sequence bit-for-bit: both run
/// the same `sweep_prepared` chaining over the same preparation kind.
#[test]
fn path_job_matches_offline_runner_bit_for_bit() {
    for (n, p, seed) in [(40usize, 60usize, 803u64), (150, 12, 804)] {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p / 2),
            seed,
            ..Default::default()
        });
        let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
        let grid = runner.derive_grid(&d);
        assert!(!grid.is_empty());

        // offline: prepared reuse + warm starts inside PathRunner::run
        let sven_solver = Sven::new(RustBackend::default());
        let offline = runner.run(&d, &sven_solver, &grid).unwrap();

        // service: the same grid as one path job
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 4 },
            ..Default::default()
        });
        let rx = service
            .submit_path(
                9,
                Arc::new(Design::from(d.x.clone())),
                Arc::new(d.y.clone()),
                runner.grid_points(&grid),
                BackendChoice::Rust,
            )
            .unwrap();
        let served = rx.recv().unwrap().result.expect("path ok").expect_path();
        service.shutdown();

        assert_eq!(served.len(), offline.len());
        for (i, (off, srv)) in offline.iter().zip(&served).enumerate() {
            assert_eq!(off.beta.len(), srv.beta.len());
            for j in 0..off.beta.len() {
                assert_eq!(
                    off.beta[j].to_bits(),
                    srv.beta[j].to_bits(),
                    "{n}x{p} point {i} j={j}: offline {} vs served {}",
                    off.beta[j],
                    srv.beta[j]
                );
            }
            assert_eq!(off.iterations, srv.iterations, "{n}x{p} point {i}");
        }
    }
}

/// Submissions after `close()` come back as `Err(JobError::Closed)` —
/// the caller can tell "queued" from "rejected".
#[test]
fn closed_service_rejects_submissions() {
    let d = synth_regression(&SynthSpec {
        n: 20,
        p: 10,
        support: 4,
        seed: 805,
        ..Default::default()
    });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 1, queue_capacity: 4 },
        ..Default::default()
    });
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    // accepted while open
    let rx = service
        .submit_point(1, x.clone(), y.clone(), 0.4, 0.5, BackendChoice::Rust)
        .expect("open service accepts");
    rx.recv().unwrap().result.expect("solve ok");
    service.close();
    let rejected = service.submit_point(1, x, y, 0.4, 0.5, BackendChoice::Rust);
    assert!(rejected.is_err(), "closed service must reject");
    assert_eq!(service.metrics().rejected(), 1);
    assert_eq!(service.metrics().submitted(), 1);
    service.shutdown();
}

/// The segmented path engine's headline contract: a long `Path` grid
/// split across 1/2/8 workers (speculative warm starts handed across
/// segments) must reproduce the offline `PathRunner::run` coefficient
/// sequence **bit-for-bit**, in both SVM regimes. The speculative
/// endpoint solve makes segments independent; the dual active-set
/// solver's final iterate is the exact Cholesky solve on the final free
/// set — warm-start-invariant — and the primal ignores dual warm starts,
/// so the chain cut cannot move a single bit.
#[test]
fn segmented_path_job_matches_offline_runner_bit_for_bit() {
    // (n, p) regimes: 2p > n ⇒ primal, n ≥ 2p ⇒ dual.
    for (n, p, seed) in [(40usize, 60usize, 811u64), (160, 12, 812)] {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p / 2),
            seed,
            ..Default::default()
        });
        let runner = PathRunner::new(PathRunnerConfig { grid: 12, ..Default::default() });
        let grid = runner.derive_grid(&d);
        assert!(grid.len() >= 4, "grid too small to segment: {}", grid.len());

        let sven_solver = Sven::new(RustBackend::default());
        let offline = runner.run(&d, &sven_solver, &grid).unwrap();
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());

        for workers in [1usize, 2, 8] {
            // path_segment_min: 2 forces segmentation wherever workers
            // allow it (grid of ~12 ⇒ up to 6 segments).
            let service = Service::start(ServiceConfig {
                pool: PoolConfig { workers, queue_capacity: 32 },
                path_segment_min: 2,
                ..Default::default()
            });
            let rx = service
                .submit_path(
                    9,
                    x.clone(),
                    y.clone(),
                    runner.grid_points(&grid),
                    BackendChoice::Rust,
                )
                .unwrap();
            let served = rx.recv().unwrap().result.expect("path ok").expect_path();
            let segments = service.metrics().path_segments();
            if workers > 1 {
                assert!(
                    segments >= 2,
                    "{n}x{p} workers={workers}: expected a split, got {segments} segments"
                );
            } else {
                assert_eq!(segments, 0, "one worker must not segment");
            }
            assert_eq!(service.metrics().completed(), 1);
            service.shutdown();

            assert_eq!(served.len(), offline.len());
            for (i, (off, srv)) in offline.iter().zip(&served).enumerate() {
                for j in 0..off.beta.len() {
                    assert_eq!(
                        off.beta[j].to_bits(),
                        srv.beta[j].to_bits(),
                        "{n}x{p} workers={workers} point {i} j={j}: \
                         offline {} vs served {}",
                        off.beta[j],
                        srv.beta[j]
                    );
                }
            }
        }
    }
}

/// Path-engine metrics are live: a served path job reports its total
/// inner-CG work, and primal-regime solves report panel gathers.
#[test]
fn path_engine_metrics_are_live() {
    // Primal regime (2p > n) so the shrinking Newton (CG + gathers) runs.
    let d = synth_regression(&SynthSpec {
        n: 30,
        p: 40,
        support: 6,
        seed: 813,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 6, ..Default::default() });
    let grid = runner.derive_grid(&d);
    assert!(!grid.is_empty());
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 2, queue_capacity: 8 },
        path_segment_min: 2,
        ..Default::default()
    });
    let rx = service
        .submit_path(
            1,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            runner.grid_points(&grid),
            BackendChoice::Rust,
        )
        .unwrap();
    rx.recv().unwrap().result.expect("path ok");
    let m = service.metrics();
    assert!(m.cg_iters_total() > 0, "primal solves must report CG iterations");
    let report = m.report();
    assert!(report.contains("cg_iters_total="), "report: {report}");
    assert!(report.contains("path_segments="), "report: {report}");
    service.shutdown();
}

/// The CV-fold workload's headline contract: a `JobKind::CvPath` job
/// must reproduce k standalone `JobKind::Path` jobs on the fold
/// training sets **bit-for-bit**, in both SVM regimes, while building
/// exactly one preparation per fold (plus one for the winning refit)
/// regardless of the fold×segment fan-out across workers — pinned via
/// the prep and cv metrics.
#[test]
fn cv_path_matches_standalone_fold_paths_bit_for_bit() {
    // (n, p) regimes: 2p > n ⇒ primal, n ≥ 2p ⇒ dual.
    for (n, p, seed) in [(40usize, 60usize, 821u64), (160, 12, 822)] {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p / 2),
            seed,
            ..Default::default()
        });
        let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
        let grid = runner.derive_grid(&d);
        let mut points = runner.grid_points(&grid);
        points.retain(|gp| gp.t > 0.0); // drop a possible all-zero-support point
        assert!(points.len() >= 4, "grid too small: {}", points.len());
        let x = Arc::new(Design::from(d.x.clone()));
        let y = Arc::new(d.y.clone());
        let folds = 3usize;

        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 4, queue_capacity: 32 },
            path_segment_min: 2,
            ..Default::default()
        });
        let rx = service
            .submit_cv_path(5, x.clone(), y.clone(), folds, points.clone(), BackendChoice::Rust)
            .unwrap();
        let cvres = rx.recv().unwrap().result.expect("cv ok").expect_cv_path();
        let m = service.metrics();
        assert_eq!(m.cv_folds(), folds as u64, "{n}x{p}: one fold build each");
        assert_eq!(
            m.prep_builds(),
            folds as u64 + 1,
            "{n}x{p}: one prep per fold + the winning refit, despite {} workers",
            4
        );
        assert_eq!(m.completed(), 1);
        let report = m.report();
        assert!(report.contains("cv_folds="), "report: {report}");
        assert!(report.contains("batched_cg_rhs_total="), "report: {report}");
        assert!(report.contains("batch_panel_rebuilds="), "report: {report}");
        service.shutdown();

        assert_eq!(cvres.fold_paths.len(), folds);
        assert_eq!(cvres.cv_errors.len(), points.len());
        assert!(cvres.cv_errors.iter().all(|e| e.is_finite() && *e >= 0.0));
        let mut argmin = 0;
        for (i, &e) in cvres.cv_errors.iter().enumerate() {
            if e < cvres.cv_errors[argmin] {
                argmin = i;
            }
        }
        assert_eq!(cvres.best_index, argmin);
        assert_eq!(cvres.best.beta.len(), p);

        // k standalone path jobs on the fold training sets, built with
        // the same public fold helpers the service uses.
        for f in 0..folds {
            let (xf, yf) = fold_problem(&x, &y, folds, f);
            let service = Service::start(ServiceConfig {
                pool: PoolConfig { workers: 4, queue_capacity: 32 },
                path_segment_min: 2,
                ..Default::default()
            });
            let rx = service
                .submit_path(9, xf, yf, points.clone(), BackendChoice::Rust)
                .unwrap();
            let alone = rx.recv().unwrap().result.expect("path ok").expect_path();
            service.shutdown();
            assert_eq!(alone.len(), cvres.fold_paths[f].len());
            for (i, (a, b)) in alone.iter().zip(&cvres.fold_paths[f]).enumerate() {
                assert_eq!(a.beta.len(), b.beta.len());
                for j in 0..a.beta.len() {
                    assert_eq!(
                        a.beta[j].to_bits(),
                        b.beta[j].to_bits(),
                        "{n}x{p} fold {f} point {i} j={j}: standalone {} vs cv {}",
                        a.beta[j],
                        b.beta[j]
                    );
                }
                assert_eq!(a.iterations, b.iterations, "{n}x{p} fold {f} point {i}");
            }
        }
    }
}

/// Batch fusion stats flow out of `sweep_prepared` and into the
/// metrics: a primal-mode sweep whose grid repeats a point (shrinking
/// forced always-on) must drive right-hand sides through blocked CG
/// over a shared panel — and the duplicated points must come back
/// bit-identical.
#[test]
fn sweep_reports_batch_fusion_stats() {
    let d = synth_regression(&SynthSpec {
        n: 20,
        p: 40,
        support: 6,
        seed: 823,
        ..Default::default()
    });
    let x = Arc::new(Design::from(d.x.clone()));
    let y = Arc::new(d.y.clone());
    let mut backend = RustBackend::default();
    // Gather from round one: every sample starts inside the margin at
    // w = 0, so all three points share the full SV set and group.
    backend.primal.shrink_max_frac = 1.0;
    let sven_solver = Sven::new(backend);
    let prep = sven_solver.prepare_shared(&x, &y).unwrap();
    let mut scratch = SvmScratch::new();
    let gp = GridPoint { t: 0.5, lambda2: 0.4 };
    let grid = vec![gp, gp, GridPoint { t: 0.8, lambda2: 0.4 }];
    let (sols, stats) = sweep_prepared(
        &sven_solver,
        prep.as_ref(),
        &mut scratch,
        &x,
        &y,
        &grid,
        None,
        true,
        None,
        None,
    )
    .unwrap();
    assert_eq!(sols.len(), 3);
    assert!(stats.batched_rhs >= 2, "duplicated points must group: {stats:?}");
    assert!(stats.panel_builds >= 1, "the group must gather a shared panel");
    for j in 0..sols[0].beta.len() {
        assert_eq!(
            sols[0].beta[j].to_bits(),
            sols[1].beta[j].to_bits(),
            "duplicated grid points must solve identically (j={j})"
        );
    }
}

/// The whole-screen workload's headline contract: a
/// `JobKind::MultiResponse` job must reproduce R standalone
/// `JobKind::Path` jobs on (X, yᵣ) **bit-for-bit** (β bits *and*
/// iteration counts) in both SVM regimes, over dense and sparse
/// designs, at 1/2/8 workers — while the whole comparison builds
/// exactly one preparation (solo jobs and the screen all share it).
/// In the primal cases one response is all-zero: λ_max screening must
/// skip its solves yet report the identical full-length path, and must
/// never change which grid points any response reports.
#[test]
fn multi_response_job_matches_standalone_path_jobs_bit_for_bit() {
    // (n, p, seed, sparse): 2p > n ⇒ primal (fused batch + screening),
    // n ≥ 2p ⇒ dual (per-response warm chains, screening off).
    for (n, p, seed, sparse) in [
        (40usize, 60usize, 831u64, false),
        (40, 60, 832, true),
        (160, 12, 833, false),
    ] {
        let primal = 2 * p > n;
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p / 2),
            seed,
            ..Default::default()
        });
        let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
        let grid = runner.derive_grid(&d);
        let mut points = runner.grid_points(&grid);
        points.retain(|gp| gp.t > 0.0);
        assert!(points.len() >= 4, "grid too small: {}", points.len());
        let x = if sparse {
            Arc::new(Design::from(Csr::from_dense(&d.x, 0.0)))
        } else {
            Arc::new(Design::from(d.x.clone()))
        };
        let responses: Vec<Arc<Vec<f64>>> = (0..5)
            .map(|r| {
                if primal && r == 2 {
                    // Screening target: all-zero bits, primal only (the
                    // dual solver path is never screened).
                    Arc::new(vec![0.0; n])
                } else {
                    let f = 0.7 + 0.2 * r as f64;
                    Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
                }
            })
            .collect();

        for workers in [1usize, 2, 8] {
            let service = Service::start(ServiceConfig {
                pool: PoolConfig { workers, queue_capacity: 64 },
                path_segment_min: 2,
                ..Default::default()
            });
            // R standalone path jobs, one per response, same dataset id.
            let alone: Vec<Vec<_>> = responses
                .iter()
                .map(|y| {
                    let rx = service
                        .submit_path(3, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
                        .unwrap();
                    rx.recv().unwrap().result.expect("solo path ok").expect_path()
                })
                .collect();
            // One MultiResponse job over the same responses and grid.
            let rx = service
                .submit_multi_response(
                    3,
                    x.clone(),
                    responses.clone(),
                    points.clone(),
                    BackendChoice::Rust,
                )
                .unwrap();
            let multi = rx.recv().unwrap().result.expect("screen ok").expect_multi_response();
            let m = service.metrics();
            assert_eq!(
                m.prep_builds(),
                1,
                "{n}x{p} sparse={sparse} workers={workers}: solo jobs and the screen \
                 must share one preparation"
            );
            assert_eq!(m.responses_total(), responses.len() as u64);
            assert_eq!(
                m.responses_screened_out(),
                if primal { 1 } else { 0 },
                "{n}x{p} sparse={sparse}: screening fires exactly on the zero response"
            );
            service.shutdown();

            assert_eq!(multi.paths.len(), responses.len());
            assert_eq!(multi.lambda_max.len(), responses.len());
            assert_eq!(multi.screened.len(), responses.len());
            assert!(multi.early_stopped_at.iter().all(|s| s.is_none()));
            for (r, (a, b)) in alone.iter().zip(&multi.paths).enumerate() {
                let want_screened = primal && r == 2;
                assert_eq!(multi.screened[r], want_screened, "{n}x{p} response {r}");
                if want_screened {
                    assert_eq!(multi.lambda_max[r], 0.0);
                }
                // Screening must never change which grid points a
                // response reports: always the full grid here.
                assert_eq!(a.len(), points.len());
                assert_eq!(b.len(), points.len(), "{n}x{p} response {r} path length");
                for (i, (sa, sb)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        sa.iterations, sb.iterations,
                        "{n}x{p} sparse={sparse} workers={workers} response {r} point {i}"
                    );
                    for j in 0..sa.beta.len() {
                        assert_eq!(
                            sa.beta[j].to_bits(),
                            sb.beta[j].to_bits(),
                            "{n}x{p} sparse={sparse} workers={workers} response {r} \
                             point {i} j={j}: solo {} vs screen {}",
                            sa.beta[j],
                            sb.beta[j]
                        );
                    }
                }
            }
        }
    }
}

/// Opt-in early stopping trades the tail of a response's path for
/// throughput: with an aggressive plateau threshold the screen reports
/// a truncated path whose solved prefix is **bit-identical** to the
/// full-grid run, and the `responses_early_stopped` counter goes live.
#[test]
fn multi_response_early_stop_truncates_but_keeps_prefix_bits() {
    let d = synth_regression(&SynthSpec {
        n: 30,
        p: 40,
        support: 6,
        seed: 841,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
    let grid = runner.derive_grid(&d);
    let mut points = runner.grid_points(&grid);
    points.retain(|gp| gp.t > 0.0);
    assert!(points.len() >= 4, "grid too small: {}", points.len());
    let x = Arc::new(Design::from(d.x.clone()));
    let responses: Vec<Arc<Vec<f64>>> = (0..2)
        .map(|r| {
            let f = 1.0 + 0.4 * r as f64;
            Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
        })
        .collect();
    let run = |early_stop: Option<f64>| {
        let service = Service::start(ServiceConfig {
            pool: PoolConfig { workers: 2, queue_capacity: 16 },
            multi_response_early_stop: early_stop,
            ..Default::default()
        });
        let rx = service
            .submit_multi_response(
                1,
                x.clone(),
                responses.clone(),
                points.clone(),
                BackendChoice::Rust,
            )
            .unwrap();
        let res = rx.recv().unwrap().result.expect("screen ok").expect_multi_response();
        let stopped = service.metrics().responses_early_stopped();
        let report = service.metrics().report();
        service.shutdown();
        (res, stopped, report)
    };
    let (full, stopped_full, _) = run(None);
    assert!(full.early_stopped_at.iter().all(|s| s.is_none()));
    assert_eq!(stopped_full, 0);
    // A deviance drop of < 99.9% between adjacent grid points counts as
    // a plateau — every realistic path retires almost immediately.
    let (cut, stopped_cut, report) = run(Some(0.999));
    assert!(stopped_cut >= 1, "aggressive threshold must stop something");
    assert!(report.contains("responses_early_stopped="), "report: {report}");
    let mut any_truncated = false;
    for (r, path) in cut.paths.iter().enumerate() {
        match cut.early_stopped_at[r] {
            Some(k) => {
                assert_eq!(path.len(), k + 1, "response {r}: path ends at the stop point");
                assert!(path.len() < full.paths[r].len(), "response {r} must truncate");
                any_truncated = true;
            }
            None => assert_eq!(path.len(), full.paths[r].len()),
        }
        // The solved prefix is bit-for-bit the full run's prefix.
        for (i, (sa, sb)) in full.paths[r].iter().zip(path).enumerate() {
            assert_eq!(sa.iterations, sb.iterations, "response {r} point {i}");
            for j in 0..sa.beta.len() {
                assert_eq!(
                    sa.beta[j].to_bits(),
                    sb.beta[j].to_bits(),
                    "response {r} point {i} j={j}: full {} vs early-stopped {}",
                    sa.beta[j],
                    sb.beta[j]
                );
            }
        }
    }
    assert!(any_truncated);
}

/// Segment hand-off serializes instead of speculating when the queue
/// lets it: with one worker wedged on a long job, the free worker runs
/// both segments of a split path back to back, so segment 2 consumes
/// segment 1's landed warm (the `segment_handoffs` counter) instead of
/// re-solving the boundary point — and the result still matches the
/// offline runner bit-for-bit.
#[test]
fn segment_handoff_serializes_when_worker_is_busy() {
    // The wedge: one expensive primal point job (n=300, p=500) that a
    // worker grinds on while the other runs the cheap segmented path.
    let big = synth_regression(&SynthSpec {
        n: 300,
        p: 500,
        support: 20,
        seed: 851,
        ..Default::default()
    });
    let small = synth_regression(&SynthSpec {
        n: 160,
        p: 12,
        support: 6,
        seed: 852,
        ..Default::default()
    });
    let runner = PathRunner::new(PathRunnerConfig { grid: 8, ..Default::default() });
    let grid = runner.derive_grid(&small);
    assert!(grid.len() >= 4, "grid too small: {}", grid.len());
    let points = runner.grid_points(&grid);

    let sven_solver = Sven::new(RustBackend::default());
    let offline = runner.run(&small, &sven_solver, &grid).unwrap();

    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 2, queue_capacity: 16 },
        path_segment_min: 2,
        ..Default::default()
    });
    // FIFO queue: [big point, segment 1, segment 2]. One worker takes
    // the big point; the other takes segment 1, publishes its final
    // warm, then takes segment 2 and finds the hand-off waiting.
    let rx_big = service
        .submit_point(
            1,
            Arc::new(Design::from(big.x.clone())),
            Arc::new(big.y.clone()),
            0.5,
            0.5,
            BackendChoice::Rust,
        )
        .unwrap();
    let rx_path = service
        .submit_path(
            2,
            Arc::new(Design::from(small.x.clone())),
            Arc::new(small.y.clone()),
            points,
            BackendChoice::Rust,
        )
        .unwrap();
    let served = rx_path.recv().unwrap().result.expect("path ok").expect_path();
    rx_big.recv().unwrap().result.expect("big point ok");
    let m = service.metrics();
    assert!(m.path_segments() >= 2, "the path must have split");
    assert!(
        m.segment_handoffs() >= 1,
        "the serialized segment must consume the landed warm, not speculate"
    );
    let report = m.report();
    assert!(report.contains("segment_handoffs="), "report: {report}");
    service.shutdown();

    assert_eq!(served.len(), offline.len());
    for (i, (off, srv)) in offline.iter().zip(&served).enumerate() {
        for j in 0..off.beta.len() {
            assert_eq!(
                off.beta[j].to_bits(),
                srv.beta[j].to_bits(),
                "handed-off segment moved bits at point {i} j={j}"
            );
        }
    }
}

/// A segmented path job with an invalid late grid point fails fast at
/// submission — before any segment burns a sweep — with the same
/// accepted-then-failed semantics as a worker-side rejection.
#[test]
fn segmented_path_with_bad_point_fails_fast() {
    let d = synth_regression(&SynthSpec {
        n: 24,
        p: 10,
        support: 4,
        seed: 814,
        ..Default::default()
    });
    let service = Service::start(ServiceConfig {
        pool: PoolConfig { workers: 4, queue_capacity: 16 },
        path_segment_min: 2,
        ..Default::default()
    });
    // 8 valid points, then one with t = NaN at the very end.
    let mut grid: Vec<sven::coordinator::GridPoint> = (0..8)
        .map(|i| sven::coordinator::GridPoint { t: 0.2 + 0.1 * i as f64, lambda2: 0.5 })
        .collect();
    grid.push(sven::coordinator::GridPoint { t: f64::NAN, lambda2: 0.5 });
    let rx = service
        .submit_path(
            1,
            Arc::new(Design::from(d.x.clone())),
            Arc::new(d.y.clone()),
            grid,
            BackendChoice::Rust,
        )
        .expect("submission accepted");
    let out = rx.recv().unwrap();
    let err = out.result.unwrap_err().to_string();
    assert!(err.contains("t must be positive"), "got: {err}");
    let m = service.metrics();
    assert_eq!(m.submitted(), 1);
    assert_eq!(m.failed(), 1);
    assert_eq!(m.path_segments(), 0, "no segment may run for an invalid grid");
    assert_eq!(m.prep_builds(), 0, "no preparation may be built for an invalid grid");
    service.shutdown();
}
