//! Rust-side half of the padding-exactness proof: the XLA backend pads
//! problems into shape buckets with a validity mask; solutions must be
//! bit-for-bit consistent with the snug (unpadded) rust solve.
//! (The python half is python/tests/test_padding.py.)

use sven::data::{synth_regression, SynthSpec};
use sven::runtime::engine::{pad_matrix, pad_vec, sample_mask, unpad_alpha};
use sven::solvers::elastic_net::EnProblem;
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::sven::{RustBackend, Sven};

fn problem(n: usize, p: usize, seed: u64) -> Option<EnProblem> {
    let d = synth_regression(&SynthSpec { n, p, support: 6, seed, ..Default::default() });
    let lambda = glmnet::cd::lambda_max(&d.x, &d.y, 0.5) * 0.3;
    let g = glmnet::solve_penalized(
        &d.x,
        &d.y,
        lambda,
        &GlmnetConfig { tol: 1e-13, ..Default::default() },
        None,
    );
    let t = sven::linalg::vecops::norm1(&g.beta);
    if t < 1e-10 {
        return None;
    }
    Some(EnProblem::new(d.x, d.y, t, n as f64 * lambda * 0.5))
}

#[test]
fn pad_helpers_are_exact() {
    let m = pad_matrix(&[1., 2., 3., 4., 5., 6.], 2, 3, 4, 5);
    assert_eq!(m.len(), 20);
    assert_eq!(&m[0..3], &[1., 2., 3.]);
    assert_eq!(&m[5..8], &[4., 5., 6.]);
    assert!(m[3] == 0.0 && m[10] == 0.0);
    assert_eq!(pad_vec(&[1., 2.], 5), vec![1., 2., 0., 0., 0.]);
    let mask = sample_mask(3, 5);
    assert_eq!(mask, vec![1., 1., 1., 0., 0., 1., 1., 1., 0., 0.]);
    let alpha = unpad_alpha(&[1., 2., 3., 0., 0., 4., 5., 6., 0., 0.], 3, 5);
    assert_eq!(alpha, vec![1., 2., 3., 4., 5., 6.]);
}

/// XLA (bucket-padded) vs rust (snug) on a problem that does NOT fill its
/// bucket: (20, 40) in the (32, 64) bucket, padding ratio ≈ 2.6×.
#[test]
fn padded_xla_equals_snug_rust_primal() {
    if !sven::runtime::default_artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let Some(prob) = problem(20, 40, 601) else { return };
    let xla = Sven::new(sven::runtime::XlaBackend::from_default_dir().unwrap());
    let rust = Sven::new(RustBackend::default());
    let bx = xla.solve(&prob).unwrap();
    let br = rust.solve(&prob).unwrap();
    for j in 0..prob.p() {
        assert!((bx.beta[j] - br.beta[j]).abs() < 1e-6, "j={j}");
    }
}

/// Dual-mode padding: (150, 12) pads into gram (256, 16) + dual p=16.
#[test]
fn padded_xla_equals_snug_rust_dual() {
    if !sven::runtime::default_artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let Some(prob) = problem(150, 12, 602) else { return };
    let xla = Sven::new(sven::runtime::XlaBackend::from_default_dir().unwrap());
    let rust = Sven::new(RustBackend::default());
    let bx = xla.solve(&prob).unwrap();
    let br = rust.solve(&prob).unwrap();
    for j in 0..prob.p() {
        assert!((bx.beta[j] - br.beta[j]).abs() < 1e-6, "j={j}");
    }
}

/// Two different problems sharing one bucket must not contaminate each
/// other through the padded region (regression test for mask reuse).
#[test]
fn bucket_sharing_no_cross_contamination() {
    if !sven::runtime::default_artifact_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let xla = Sven::new(sven::runtime::XlaBackend::from_default_dir().unwrap());
    let rust = Sven::new(RustBackend::default());
    for seed in [603u64, 604, 605] {
        // different shapes, same (32, 64) bucket
        for (n, p) in [(18usize, 35usize), (25, 50), (30, 60)] {
            let Some(prob) = problem(n, p, seed ^ (n * p) as u64) else { continue };
            let bx = xla.solve(&prob).unwrap();
            let br = rust.solve(&prob).unwrap();
            for j in 0..prob.p() {
                assert!(
                    (bx.beta[j] - br.beta[j]).abs() < 1e-6,
                    "({n},{p}) seed {seed} j={j}"
                );
            }
        }
    }
}
