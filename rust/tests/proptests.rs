//! Property-based tests over the coordinator and solver invariants,
//! using the in-tree `forall` framework (rust/src/testing).

use sven::data::{synth_regression, SynthSpec};
use sven::linalg::{vecops, Csr, Design, Mat};
use sven::rng::Rng;
use sven::solvers::elastic_net::{penalized_to_constrained, EnProblem};
use sven::solvers::glmnet::{self, CdMode, GlmnetConfig};
use sven::solvers::sven::{backmap, effective_c, RustBackend, Sven, SvmMode};
use sven::testing::prop::{close, close_vec, forall};

/// Generator: a random standardized regression problem, sized by `size`.
fn gen_problem(rng: &mut Rng, size: usize) -> (Mat, Vec<f64>, u64) {
    let n = 10 + (rng.below(8) + size) * 3;
    let p = 5 + (rng.below(10) + size) * 4;
    let seed = rng.next_u64();
    let d = synth_regression(&SynthSpec {
        n,
        p,
        support: 4.min(p),
        rho: rng.uniform_in(0.0, 0.8),
        seed,
        ..Default::default()
    });
    (d.x, d.y, seed)
}

#[test]
fn prop_sven_matches_glmnet() {
    forall("sven == glmnet on random problems", 20, gen_problem, |(x, y, _)| {
        let kappa = 0.5;
        let lambda = glmnet::cd::lambda_max(x, y, kappa) * 0.3;
        let g = glmnet::solve_penalized(
            x,
            y,
            lambda,
            &GlmnetConfig { kappa, tol: 1e-12, ..Default::default() },
            None,
        );
        let (t, lambda2) = penalized_to_constrained(&g.beta, lambda, kappa, x.rows());
        if t < 1e-10 {
            return Ok(());
        }
        let sol = Sven::new(RustBackend::default())
            .solve(&EnProblem::new(x.clone(), y.clone(), t, lambda2))
            .map_err(|e| e.to_string())?;
        close_vec(&sol.beta, &g.beta, 1e-3, "beta")
    });
}

#[test]
fn prop_primal_dual_agree() {
    forall("primal α == dual α", 14, gen_problem, |(x, y, _)| {
        use std::sync::Arc;
        use sven::solvers::sven::{SvmBackend, SvmScratch};
        let backend = RustBackend::default();
        let design: Arc<Design> = Arc::new(x.clone().into());
        let y = Arc::new(y.clone());
        let prim =
            backend.prepare(&design, &y, SvmMode::Primal).map_err(|e| e.to_string())?;
        let dual =
            backend.prepare(&design, &y, SvmMode::Dual).map_err(|e| e.to_string())?;
        let (t, c) = (0.7, 4.0);
        let mut scratch = SvmScratch::new();
        let a = prim.solve(t, c, None, &mut scratch, None).map_err(|e| e.to_string())?.alpha;
        let b = dual.solve(t, c, None, &mut scratch, None).map_err(|e| e.to_string())?.alpha;
        close_vec(&a, &b, 1e-4, "alpha")
    });
}

#[test]
fn prop_backmap_l1_bound() {
    // |backmap(α)|₁ ≤ t for every non-negative α.
    forall(
        "backmap respects the budget",
        64,
        |rng: &mut Rng, size: usize| {
            let p = 1 + size;
            let alpha: Vec<f64> = (0..2 * p).map(|_| rng.uniform() * 3.0).collect();
            let t = rng.uniform_in(0.1, 10.0);
            (alpha, p, t)
        },
        |(alpha, p, t)| {
            let (beta, _) = backmap(alpha, *p, *t);
            let l1 = vecops::norm1(&beta);
            if l1 <= t * (1.0 + 1e-9) {
                Ok(())
            } else {
                Err(format!("|β|₁ = {l1} > t = {t}"))
            }
        },
    );
}

#[test]
fn prop_backmap_scale_invariance() {
    forall(
        "backmap is scale-invariant in α",
        64,
        |rng: &mut Rng, size: usize| {
            let p = 1 + size;
            let alpha: Vec<f64> = (0..2 * p).map(|_| rng.uniform()).collect();
            let scale = rng.uniform_in(0.1, 100.0);
            (alpha, p, scale)
        },
        |(alpha, p, scale)| {
            let (b1, _) = backmap(alpha, *p, 1.0);
            let scaled: Vec<f64> = alpha.iter().map(|a| a * scale).collect();
            let (b2, _) = backmap(&scaled, *p, 1.0);
            close_vec(&b1, &b2, 1e-9, "beta")
        },
    );
}

#[test]
fn prop_effective_c_monotone() {
    forall(
        "C(λ₂) is monotone decreasing",
        64,
        |rng: &mut Rng, _| (rng.uniform_in(1e-8, 10.0), rng.uniform_in(1e-8, 10.0)),
        |(a, b)| {
            let (lo, hi) = if a < b { (*a, *b) } else { (*b, *a) };
            if effective_c(lo, 1e10) >= effective_c(hi, 1e10) {
                Ok(())
            } else {
                Err(format!("C not monotone at {lo} vs {hi}"))
            }
        },
    );
}

#[test]
fn prop_objective_at_solution_not_worse_than_truth() {
    // The solver's objective must beat (or tie) the generating ground
    // truth rescaled into the budget — a sanity floor on optimality.
    forall("solution beats rescaled truth", 12, gen_problem, |(x, y, _)| {
        let kappa = 0.5;
        let lambda = glmnet::cd::lambda_max(x, y, kappa) * 0.25;
        let g = glmnet::solve_penalized(
            x,
            y,
            lambda,
            &GlmnetConfig { kappa, ..Default::default() },
            None,
        );
        let (t, lambda2) = penalized_to_constrained(&g.beta, lambda, kappa, x.rows());
        if t < 1e-10 {
            return Ok(());
        }
        let prob = EnProblem::new(x.clone(), y.clone(), t, lambda2);
        let sol = Sven::new(RustBackend::default()).solve(&prob).map_err(|e| e.to_string())?;
        // any feasible candidate: glmnet's own solution
        let cand_obj = prob.objective(&g.beta);
        if sol.objective <= cand_obj * (1.0 + 1e-6) + 1e-9 {
            Ok(())
        } else {
            Err(format!("objective {} worse than candidate {}", sol.objective, cand_obj))
        }
    });
}

#[test]
fn prop_queue_never_loses_jobs() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use sven::coordinator::{Pool, PoolConfig};
    forall(
        "pool processes exactly what was submitted",
        10,
        |rng: &mut Rng, size: usize| (1 + rng.below(4), 1 + size * 7),
        |&(workers, jobs)| {
            let done = Arc::new(AtomicUsize::new(0));
            let done2 = done.clone();
            let pool = Pool::spawn(
                &PoolConfig { workers, queue_capacity: 4 },
                |_| (),
                move |_, _job: usize| {
                    done2.fetch_add(1, Ordering::Relaxed);
                },
            );
            for i in 0..jobs {
                pool.submit(i).map_err(|_| "pool closed early".to_string())?;
            }
            pool.shutdown();
            let n = done.load(Ordering::Relaxed);
            close(n as f64, jobs as f64, 0.0, "processed count")
        },
    );
}

#[test]
fn prop_standardize_idempotent_shape() {
    forall(
        "standardized data stays standardized",
        24,
        |rng: &mut Rng, size: usize| {
            let n = 8 + size * 2;
            let p = 3 + size;
            let mean = rng.uniform_in(-3.0, 3.0);
            let x = Mat::from_fn(n, p, |_, _| rng.normal_ms(mean, 2.0));
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        },
        |(x, y)| {
            let (xs, yc, _) = sven::data::standardize(x, y);
            let (xs2, yc2, _) = sven::data::standardize(&xs, &yc);
            close_vec(xs2.data(), xs.data(), 1e-8, "X")?;
            close_vec(&yc2, &yc, 1e-8, "y")
        },
    );
}

/// Generator: a random sparse regression problem (dense twin + sparse
/// Design over identical values), sized by `size`.
fn gen_sparse_problem(rng: &mut Rng, size: usize) -> (Mat, Design, Vec<f64>) {
    let n = 16 + (rng.below(6) + size) * 4;
    let p = 10 + (rng.below(8) + size) * 5;
    let density = rng.uniform_in(0.05, 0.25);
    let mut local = Rng::seed_from(rng.next_u64());
    let x = Mat::from_fn(n, p, |_, _| {
        if local.bernoulli(density) {
            local.normal()
        } else {
            0.0
        }
    });
    // response from a sparse planted model + noise
    let beta: Vec<f64> = (0..p)
        .map(|j| if j < 5 { local.normal() } else { 0.0 })
        .collect();
    let mut y = x.matvec(&beta);
    for v in y.iter_mut() {
        *v += 0.2 * local.normal();
    }
    let design = Design::from(Csr::from_dense(&x, 0.0));
    (x, design, y)
}

/// Dense-vs-sparse solver agreement: the same naive-CD algorithm run
/// over the dense transposed copy and over the CSC mirror must land on
/// the same β (within CD tolerance) — the correctness seal on the
/// never-densify glmnet path.
#[test]
fn prop_dense_sparse_cd_agree() {
    forall("glmnet CD: dense == sparse Design", 12, gen_sparse_problem, |(x, d, y)| {
        let cfg = GlmnetConfig { mode: CdMode::Naive, tol: 1e-12, ..Default::default() };
        let lambda = glmnet::lambda_max(x, y, cfg.kappa) * 0.3;
        let dense = glmnet::solve_penalized(x, y, lambda, &cfg, None);
        let sparse = glmnet::solve_penalized_design(d, y, lambda, &cfg, None);
        close_vec(&dense.beta, &sparse.beta, 1e-6, "beta")
    });
}

/// SVEN over a sparse Design agrees with SVEN over the densified twin
/// (both SVM modes exercised through the 2p > n auto rule by the shapes
/// the generator draws).
#[test]
fn prop_dense_sparse_sven_agree() {
    forall("sven: dense == sparse Design", 8, gen_sparse_problem, |(x, d, y)| {
        let cfg = GlmnetConfig { tol: 1e-12, ..Default::default() };
        let lambda = glmnet::lambda_max(x, y, cfg.kappa) * 0.3;
        let g = glmnet::solve_penalized(x, y, lambda, &cfg, None);
        let (t, lambda2) = penalized_to_constrained(&g.beta, lambda, cfg.kappa, x.rows());
        if t < 1e-10 {
            return Ok(());
        }
        let sven = Sven::new(RustBackend::default());
        let sol_dense = sven
            .solve(&EnProblem::new(x.clone(), y.clone(), t, lambda2))
            .map_err(|e| e.to_string())?;
        let sol_sparse = sven
            .solve(&EnProblem::new(d.clone(), y.clone(), t, lambda2))
            .map_err(|e| e.to_string())?;
        close_vec(&sol_dense.beta, &sol_sparse.beta, 1e-5, "beta")
    });
}

/// The sparse determinism seal: a sparse SVEN solve run strictly serial
/// and threaded must produce bit-identical β — every threaded CSR/CSC
/// kernel (matvec, matvec_t, gram join, CSC build) keeps its fixed
/// reduction order. Shapes are sized past the sparse fan-out threshold
/// so the threaded paths actually engage.
#[test]
fn prop_sparse_parallelism_bit_stable() {
    use sven::solvers::sven::SvenConfig;
    use sven::util::Parallelism;

    let mut rng = Rng::seed_from(8642);
    // (rows, cols, density, forced mode): primal (2p > n) and dual.
    let cases = [
        (300usize, 400usize, 0.18, SvmMode::Primal),
        (900, 150, 0.15, SvmMode::Dual),
    ];
    for (n, p, density, mode) in cases {
        let x = Mat::from_fn(n, p, |_, _| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let design = Design::from(Csr::from_dense(&x, 0.0));
        assert!(design.nnz() > 1 << 14, "{n}x{p} must cross the sparse threshold");
        let run = |par: Parallelism| -> Vec<f64> {
            let sven = Sven::with_config(
                RustBackend::default(),
                SvenConfig { mode, parallelism: par, ..Default::default() },
            );
            let prob = EnProblem::new(design.clone(), y.clone(), 0.8, 0.5);
            sven.solve(&prob).expect("solve").beta
        };
        let serial = run(Parallelism::None);
        for nt in [2usize, 4] {
            let threaded = run(Parallelism::Fixed(nt));
            for j in 0..p {
                assert_eq!(
                    serial[j].to_bits(),
                    threaded[j].to_bits(),
                    "{mode:?} nt={nt} j={j}: serial {} vs threaded {}",
                    serial[j],
                    threaded[j]
                );
            }
        }
    }
}

/// The tentpole determinism seal: SVEN run strictly serial
/// (`Parallelism::None`) and threaded must produce **bit-identical** β
/// paths — the blocked kernels never let the worker count change the
/// accumulation order. Checked in both forced SVM modes across several
/// path points with warm starts.
#[test]
fn prop_parallelism_modes_bit_stable_beta_path() {
    use sven::solvers::sven::{SvenConfig, SvmWarm};
    use sven::util::Parallelism;

    let run_path = |mode: SvmMode, par: Parallelism, x: &Mat, y: &[f64]| -> Vec<Vec<f64>> {
        let sven = Sven::with_config(
            RustBackend::default(),
            SvenConfig { mode, parallelism: par, ..Default::default() },
        );
        let prep = sven.prepare(x, y).expect("prepare");
        let mut scratch = sven::solvers::sven::SvmScratch::new();
        let mut warm: Option<SvmWarm> = None;
        let mut betas = Vec::new();
        for t in [0.2, 0.5, 0.9, 1.4] {
            let prob = EnProblem::new(x.clone(), y.to_vec(), t, 0.5);
            let sol = sven
                .solve_prepared(prep.as_ref(), &mut scratch, &prob, warm.as_ref(), None)
                .expect("solve");
            // Real warm state so the warm-seeded solver paths (free-set
            // seeding, K_FF gathers on large free sets) are exercised.
            warm = Some(SvmWarm { w: None, alpha: Some(sol.beta_to_warm(t)) });
            betas.push(sol.beta);
        }
        betas
    };

    // Primal regime (2p > n) and dual regime (n ≥ 2p), sized past the
    // parallel thresholds of the GEMV/gram layers so threaded runs
    // actually fan out.
    let cases = [(260usize, 260usize, SvmMode::Primal), (900, 40, SvmMode::Dual)];
    for (n, p, mode) in cases {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 8.min(p),
            seed: 4321,
            ..Default::default()
        });
        let serial = run_path(mode, Parallelism::None, &d.x, &d.y);
        let threaded = run_path(mode, Parallelism::Fixed(4), &d.x, &d.y);
        assert_eq!(serial.len(), threaded.len());
        for (pt, (bs, bt)) in serial.iter().zip(&threaded).enumerate() {
            for j in 0..p {
                assert_eq!(
                    bs[j].to_bits(),
                    bt[j].to_bits(),
                    "{mode:?} point {pt} j={j}: serial {} vs threaded {}",
                    bs[j],
                    bt[j]
                );
            }
        }
    }
}

/// The SVEN sample operator's fused multi-RHS products must be
/// column-bit-identical to the single-RHS calls over dense *and* sparse
/// designs — the contract that lets the primal Newton batch its margin
/// refresh without changing a single iterate bit.
#[test]
fn prop_reduced_samples_multi_rhs_bit_identical() {
    use sven::linalg::MultiVec;
    use sven::solvers::svm::{ReducedSamples, SampleSet};
    forall(
        "reduced multi-RHS == single-RHS bits",
        12,
        |rng: &mut Rng, size: usize| {
            let n = 8 + rng.below(6 + 3 * size);
            let p = 5 + rng.below(8 + 4 * size);
            let density = rng.uniform_in(0.2, 0.9);
            let mut local = Rng::seed_from(rng.next_u64());
            let x = Mat::from_fn(n, p, |_, _| {
                if local.bernoulli(density) {
                    local.normal()
                } else {
                    0.0
                }
            });
            let y: Vec<f64> = (0..n).map(|_| local.normal()).collect();
            let r = 1 + local.below(3);
            let vs = MultiVec::from_fn(n, r, |_, _| local.normal());
            let us = MultiVec::from_fn(2 * p, r, |_, _| local.normal());
            (x, y, vs, us)
        },
        |(x, y, vs, us)| {
            let r = vs.ncols();
            let (n, p) = (x.rows(), x.cols());
            let designs: [Design; 2] = [x.clone().into(), Csr::from_dense(x, 0.0).into()];
            for design in &designs {
                let red = ReducedSamples::new(design, y, 0.7);
                let mut outs = MultiVec::zeros(2 * p, r);
                red.matvec_multi(vs, &mut outs);
                let mut outs_t = MultiVec::zeros(n, r);
                red.matvec_t_multi(us, &mut outs_t);
                for j in 0..r {
                    let mut single = vec![0.0; 2 * p];
                    red.matvec(vs.col(j), &mut single);
                    for (i, (s, m)) in single.iter().zip(outs.col(j)).enumerate() {
                        if s.to_bits() != m.to_bits() {
                            return Err(format!(
                                "matvec sparse={} col {j} i={i}: {s} vs {m}",
                                design.is_sparse()
                            ));
                        }
                    }
                    let mut single_t = vec![0.0; n];
                    red.matvec_t(us.col(j), &mut single_t);
                    for (i, (s, m)) in single_t.iter().zip(outs_t.col(j)).enumerate() {
                        if s.to_bits() != m.to_bits() {
                            return Err(format!(
                                "matvec_t sparse={} col {j} i={i}: {s} vs {m}",
                                design.is_sparse()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Gathered-panel Hessian products must equal the masked full-matrix
/// products (the shrinking Newton's correctness invariant): for a random
/// SV subset S, `Gᵀ(G·v)` over the gathered panel == `X̂ᵀ(1_S ⊙ (X̂·v))`
/// to floating-point tolerance, over dense and sparse designs.
#[test]
fn prop_gathered_hessian_equals_masked() {
    use sven::solvers::svm::{GatheredRows, ReducedSamples, SampleSet};
    forall(
        "gathered Hessian == masked Hessian",
        16,
        |rng: &mut Rng, size: usize| {
            let n = 6 + rng.below(5 + 3 * size);
            let p = 4 + rng.below(6 + 4 * size);
            let mut local = Rng::seed_from(rng.next_u64());
            let x = Mat::from_fn(n, p, |_, _| {
                if local.bernoulli(0.6) {
                    local.normal()
                } else {
                    0.0
                }
            });
            let y: Vec<f64> = (0..n).map(|_| local.normal()).collect();
            // random SV subset of the 2p implicit rows
            let rows: Vec<usize> = (0..2 * p).filter(|_| local.bernoulli(0.4)).collect();
            let v: Vec<f64> = (0..n).map(|_| local.normal()).collect();
            (x, y, rows, v)
        },
        |(x, y, rows, v)| {
            if rows.is_empty() {
                return Ok(());
            }
            let (n, p) = (x.rows(), x.cols());
            let designs: [Design; 2] = [x.clone().into(), Csr::from_dense(x, 0.0).into()];
            for design in &designs {
                let red = ReducedSamples::new(design, y, 0.9);
                // masked: X̂ᵀ(1_S ⊙ (X̂·v))
                let mut full = vec![0.0; 2 * p];
                red.matvec(v, &mut full);
                let in_set: Vec<bool> = {
                    let mut m = vec![false; 2 * p];
                    for &s in rows {
                        m[s] = true;
                    }
                    m
                };
                for (i, f) in full.iter_mut().enumerate() {
                    if !in_set[i] {
                        *f = 0.0;
                    }
                }
                let mut masked = vec![0.0; n];
                red.matvec_t(&full, &mut masked);
                // gathered: Gᵀ(G·v)
                let mut panel = GatheredRows::new();
                red.gather_rows_into(rows, &mut panel);
                let mut gv = vec![0.0; rows.len()];
                red.gathered_matvec(&panel, v, &mut gv);
                let mut gathered = vec![0.0; n];
                red.gathered_matvec_t(&panel, &gv, &mut gathered);
                close_vec(
                    &gathered,
                    &masked,
                    1e-9,
                    &format!("Hessian product (sparse={})", design.is_sparse()),
                )?;
            }
            Ok(())
        },
    );
}

/// Blocked CG vs solo CG: every column of a `cg_solve_multi` panel must
/// be **bit-identical** to its solo `cg_solve_with` run — same iterates,
/// same iteration counts — across panel widths 1/2/4/8 and across
/// thread counts (the fused operator products are bit-stable, and every
/// per-column scalar op replicates the solo order).
#[test]
fn prop_blocked_cg_columns_bit_match_solo_across_threads() {
    use sven::linalg::{cg_solve_multi, cg_solve_with, CgOptions, CgScratch, MultiVec};
    use sven::testing::prop::{RidgeFamily, RidgeOp};
    use sven::util::parallel::with_parallelism;
    use sven::util::Parallelism;

    forall(
        "blocked CG == solo CG per column",
        12,
        |rng: &mut Rng, size: usize| {
            let n = 8 + 3 * size + rng.below(10);
            let d = 5 + 2 * size + rng.below(8);
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let width = [1usize, 2, 4, 8][rng.below(4)];
            // Shifts spread over orders of magnitude: columns converge at
            // different iteration counts, exercising masking+compaction.
            let shifts: Vec<f64> = (0..width).map(|_| rng.uniform_in(0.05, 20.0)).collect();
            let b = MultiVec::from_fn(d, width, |_, _| rng.normal());
            (x, shifts, b)
        },
        |(x, shifts, b)| {
            let width = shifts.len();
            let d = x.cols();
            let opts = vec![CgOptions::default(); width];
            let run_multi = |par: Parallelism| -> (MultiVec, Vec<usize>) {
                with_parallelism(par, || {
                    let fam = RidgeFamily::new(x, shifts.clone());
                    let mut sol = MultiVec::zeros(d, width);
                    let out = cg_solve_multi(&fam, b, &mut sol, &opts);
                    (sol, out.outcomes.iter().map(|o| o.iters).collect())
                })
            };
            let serial = run_multi(Parallelism::None);
            for nt in [2usize, 8] {
                let threaded = run_multi(Parallelism::Fixed(nt));
                if threaded.1 != serial.1 {
                    return Err(format!("iters differ at nt={nt}"));
                }
                for (i, (s, t)) in
                    serial.0.data().iter().zip(threaded.0.data()).enumerate()
                {
                    if s.to_bits() != t.to_bits() {
                        return Err(format!("nt={nt} flat index {i}"));
                    }
                }
            }
            // Per-column solo reference, serial.
            with_parallelism(Parallelism::None, || {
                for j in 0..width {
                    let op = RidgeOp::new(x, shifts[j]);
                    let mut xs = vec![0.0; d];
                    let out = cg_solve_with(
                        &op,
                        b.col(j),
                        &mut xs,
                        &CgOptions::default(),
                        &mut CgScratch::new(),
                    );
                    if out.iters != serial.1[j] {
                        return Err(format!(
                            "col {j}: solo {} iters vs blocked {}",
                            out.iters, serial.1[j]
                        ));
                    }
                    for (i, (s, m)) in xs.iter().zip(serial.0.col(j)).enumerate() {
                        if s.to_bits() != m.to_bits() {
                            return Err(format!("col {j} i={i}: solo vs blocked bits"));
                        }
                    }
                }
                Ok(())
            })
        },
    );
}

/// The batched primal Newton is transparent at the solver-output level:
/// a batch over random neighboring (t, C) points must reproduce each
/// solo `primal_newton` run bit-for-bit (weights, duals, counters).
#[test]
fn prop_primal_newton_batch_matches_solo() {
    use sven::solvers::svm::samples::reduction_labels;
    use sven::solvers::svm::{
        primal_newton, primal_newton_batch, PrimalBatchPoint, PrimalOptions, ReducedSamples,
    };
    forall(
        "primal batch == solo",
        8,
        |rng: &mut Rng, size: usize| {
            let n = 8 + 2 * size + rng.below(6);
            let p = n + 4 + rng.below(10); // 2p > n ⇒ the primal regime
            let x = Mat::from_fn(n, p, |_, _| rng.normal());
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let width = 1 + rng.below(4);
            let pts: Vec<(f64, f64)> = (0..width)
                .map(|_| (rng.uniform_in(0.2, 2.0), rng.uniform_in(0.5, 10.0)))
                .collect();
            (x, y, pts)
        },
        |(x, y, pts)| {
            let design: Design = x.clone().into();
            let labels = reduction_labels(x.cols());
            let opts = PrimalOptions::default();
            let points: Vec<PrimalBatchPoint> = pts
                .iter()
                .map(|&(t, c)| PrimalBatchPoint { t, c, w0: None })
                .collect();
            let (batch, _stats) = primal_newton_batch(&design, y, &points, &opts, None, None);
            for (s, &(t, c)) in batch.iter().zip(pts) {
                let red = ReducedSamples::new(&design, y, t);
                let solo = primal_newton(&red, &labels, c, &opts, None);
                if solo.newton_iters != s.newton_iters
                    || solo.cg_iters_total != s.cg_iters_total
                    || solo.gather_rebuilds != s.gather_rebuilds
                {
                    return Err(format!(
                        "t={t} c={c}: counters diverge (newton {} vs {}, cg {} vs {}, \
                         gathers {} vs {})",
                        solo.newton_iters,
                        s.newton_iters,
                        solo.cg_iters_total,
                        s.cg_iters_total,
                        solo.gather_rebuilds,
                        s.gather_rebuilds
                    ));
                }
                for (i, (a, b)) in solo.w.iter().zip(&s.w).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("t={t} c={c}: w[{i}] bits"));
                    }
                }
                for (i, (a, b)) in solo.alpha.iter().zip(&s.alpha).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("t={t} c={c}: alpha[{i}] bits"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Mixed-precision agreement seal: a `Precision::MixedF32` solve must
/// land within solver tolerance of the all-f64 solve over dense and
/// sparse designs in both forced SVM modes. The dual backend ignores
/// the mixed setting entirely (its active-set Cholesky stays f64), so
/// its two runs must agree to the bit; the primal runs must actually
/// have taken refinement passes for the comparison to mean anything.
#[test]
fn prop_mixed_precision_beta_agrees_with_f64() {
    use sven::linalg::Precision;
    use sven::solvers::sven::SvenConfig;

    let mut rng = Rng::seed_from(9753);
    // (n, p, density [1.0 = dense], forced mode)
    let cases = [
        (40usize, 90usize, 1.0f64, SvmMode::Primal),
        (48, 70, 0.25, SvmMode::Primal),
        (160, 24, 1.0, SvmMode::Dual),
        (200, 30, 0.2, SvmMode::Dual),
    ];
    for (n, p, density, mode) in cases {
        let x = Mat::from_fn(n, p, |_, _| {
            if rng.bernoulli(density) {
                rng.normal()
            } else {
                0.0
            }
        });
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let design = if density < 1.0 {
            Design::from(Csr::from_dense(&x, 0.0))
        } else {
            Design::from(x.clone())
        };
        let run = |precision: Precision| {
            let sven = Sven::with_config(
                RustBackend::default(),
                SvenConfig { mode, precision, ..Default::default() },
            );
            let prob = EnProblem::new(design.clone(), y.clone(), 0.8, 0.5);
            sven.solve(&prob).expect("solve")
        };
        let sol64 = run(Precision::F64);
        let sol32 = run(Precision::MixedF32);
        assert_eq!(sol64.refine_passes, 0, "{mode:?} {n}x{p}: f64 must not refine");
        if matches!(mode, SvmMode::Dual) {
            assert_eq!(sol32.refine_passes, 0, "{mode:?} {n}x{p}: dual stays f64");
            for j in 0..p {
                assert_eq!(
                    sol64.beta[j].to_bits(),
                    sol32.beta[j].to_bits(),
                    "{mode:?} {n}x{p} j={j}: dual must ignore MixedF32"
                );
            }
        } else {
            assert!(sol32.refine_passes > 0, "{mode:?} {n}x{p}: mixed primal must refine");
        }
        for j in 0..p {
            assert!(
                (sol64.beta[j] - sol32.beta[j]).abs() < 1e-5,
                "{mode:?} {n}x{p} j={j}: f64 {} vs mixed {}",
                sol64.beta[j],
                sol32.beta[j]
            );
        }
    }
}

/// Whole-screen transparency seal: a `JobKind::MultiResponse` job over
/// random shapes (dense/sparse × primal/dual × 1/2/8 workers) must
/// reproduce each response's standalone `Path` job **bit-for-bit** — β
/// bits and iteration counts — and λ_max screening (exercised via an
/// injected all-zero response in the primal draws) must never change
/// which grid points a response reports: every path spans the full grid.
#[test]
fn prop_multi_response_matches_solo_path_jobs() {
    use std::sync::Arc;
    use sven::coordinator::{
        BackendChoice, PathRunner, PathRunnerConfig, PoolConfig, Service, ServiceConfig,
    };

    forall(
        "multi-response screen == solo path jobs bits",
        8,
        |rng: &mut Rng, size: usize| {
            let primal = rng.bernoulli(0.5);
            let sparse = rng.bernoulli(0.5);
            let (n, p) = if primal {
                // 2p > n ⇒ primal: fused response×grid batches + screening.
                let n = 14 + 2 * size + rng.below(10);
                (n, n / 2 + 6 + rng.below(12))
            } else {
                // n ≥ 2p ⇒ dual: per-response warm chains, screening off.
                let p = 6 + rng.below(6);
                (2 * p + 20 + 4 * size + rng.below(16), p)
            };
            let workers = [1usize, 2, 8][rng.below(3)];
            let r = 2 + rng.below(3);
            (n, p, sparse, workers, r, rng.next_u64(), primal)
        },
        |&(n, p, sparse, workers, r, seed, primal)| {
            let d = synth_regression(&SynthSpec {
                n,
                p,
                support: 6.min(p / 2).max(1),
                seed,
                ..Default::default()
            });
            let runner = PathRunner::new(PathRunnerConfig { grid: 5, ..Default::default() });
            let grid = runner.derive_grid(&d);
            let mut points = runner.grid_points(&grid);
            points.retain(|gp| gp.t > 0.0);
            if points.len() < 2 {
                return Ok(());
            }
            let x = if sparse {
                Arc::new(Design::from(Csr::from_dense(&d.x, 0.0)))
            } else {
                Arc::new(Design::from(d.x.clone()))
            };
            let mut responses: Vec<Arc<Vec<f64>>> = (0..r)
                .map(|i| {
                    let f = 0.6 + 0.3 * i as f64;
                    Arc::new(d.y.iter().map(|&v| f * v).collect::<Vec<f64>>())
                })
                .collect();
            if primal {
                // Screening target: must come back as a synthesized
                // all-zero path bit-identical to actually solving it.
                responses.push(Arc::new(vec![0.0; n]));
            }
            let service = Service::start(ServiceConfig {
                pool: PoolConfig { workers, queue_capacity: 64 },
                path_segment_min: 2,
                ..Default::default()
            });
            let mut alone = Vec::with_capacity(responses.len());
            for y in &responses {
                let rx = service
                    .submit_path(7, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
                    .map_err(|e| e.to_string())?;
                alone.push(rx.recv().unwrap().result?.expect_path());
            }
            let rx = service
                .submit_multi_response(
                    7,
                    x.clone(),
                    responses.clone(),
                    points.clone(),
                    BackendChoice::Rust,
                )
                .map_err(|e| e.to_string())?;
            let multi = rx.recv().unwrap().result?.expect_multi_response();
            let prep_builds = service.metrics().prep_builds();
            service.shutdown();
            if prep_builds != 1 {
                return Err(format!("expected one shared prep build, got {prep_builds}"));
            }
            if multi.paths.len() != alone.len() {
                return Err("path count mismatch".into());
            }
            for (ri, (a, b)) in alone.iter().zip(&multi.paths).enumerate() {
                if a.len() != points.len() || b.len() != points.len() {
                    return Err(format!(
                        "response {ri}: screening changed the reported grid \
                         (solo {} vs screen {} of {} points)",
                        a.len(),
                        b.len(),
                        points.len()
                    ));
                }
                for (i, (sa, sb)) in a.iter().zip(b).enumerate() {
                    if sa.iterations != sb.iterations {
                        return Err(format!(
                            "response {ri} point {i}: iterations {} vs {}",
                            sa.iterations, sb.iterations
                        ));
                    }
                    for j in 0..sa.beta.len() {
                        if sa.beta[j].to_bits() != sb.beta[j].to_bits() {
                            return Err(format!(
                                "sparse={sparse} workers={workers} response {ri} \
                                 point {i} j={j}: solo {} vs screen {}",
                                sa.beta[j], sb.beta[j]
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Checkpointed-recovery seal: a path sweep killed at **every**
/// grid-point ordinal under a retry policy must reproduce the
/// uninterrupted run bit-for-bit — β bits and iteration counts — over
/// dense/sparse designs, both SVM regimes, and 1/2/8 workers. The
/// metrics must also prove the retry *resumed* from the published
/// checkpoint (primal checkpoints land at chunk boundaries, the dual
/// warm chain checkpoints after every point) rather than re-solving the
/// prefix.
#[test]
fn prop_sweep_killed_at_every_ordinal_resumes_bit_identical() {
    use std::sync::Arc;
    use sven::coordinator::{
        BackendChoice, FaultPlan, GridPoint, PoolConfig, RetryPolicy, Service,
        ServiceConfig, SubmitOptions,
    };

    // Keep in sync with coordinator::path::CTL_CHUNK: the primal sweep
    // under control batches this many points between checkpoints.
    const CTL_CHUNK: usize = 8;
    let points: Vec<GridPoint> =
        (0..10).map(|i| GridPoint { t: 0.2 + 0.05 * i as f64, lambda2: 0.5 }).collect();
    // Primal regime (2p > n, chunk-batched) and dual regime (sequential
    // warm chain). The grid spans two primal chunks so a kill in the
    // second chunk resumes from a non-empty checkpoint.
    let shapes = [(40usize, 48usize, true), (120, 30, false)];
    for (n, p, primal) in shapes {
        let d = synth_regression(&SynthSpec {
            n,
            p,
            support: 6,
            seed: 7311,
            ..Default::default()
        });
        for sparse in [false, true] {
            let x = if sparse {
                Arc::new(Design::from(Csr::from_dense(&d.x, 0.0)))
            } else {
                Arc::new(Design::from(d.x.clone()))
            };
            let y = Arc::new(d.y.clone());
            let clean_svc = Service::start(ServiceConfig {
                pool: PoolConfig { workers: 1, queue_capacity: 64 },
                ..Default::default()
            });
            let rx = clean_svc
                .submit_path(1, x.clone(), y.clone(), points.clone(), BackendChoice::Rust)
                .expect("accepted");
            let clean = rx.recv().unwrap().result.expect("clean path").expect_path();
            clean_svc.shutdown();
            assert_eq!(clean.len(), points.len());
            for workers in [1usize, 2, 8] {
                for k in 0..points.len() as u64 {
                    let ctx =
                        format!("primal={primal} sparse={sparse} workers={workers} kill={k}");
                    let svc = Service::start(ServiceConfig {
                        pool: PoolConfig { workers, queue_capacity: 64 },
                        fault_plan: Some(FaultPlan {
                            solve_panics: vec![k],
                            ..Default::default()
                        }),
                        ..Default::default()
                    });
                    let opts = SubmitOptions {
                        retry: RetryPolicy::retries(2),
                        ..Default::default()
                    };
                    let rx = svc
                        .submit_path_with(
                            1,
                            x.clone(),
                            y.clone(),
                            points.clone(),
                            BackendChoice::Rust,
                            opts,
                        )
                        .expect("accepted");
                    let sols =
                        rx.recv().unwrap().result.expect("retried to success").expect_path();
                    assert_eq!(sols.len(), clean.len(), "{ctx}");
                    for (i, (a, b)) in clean.iter().zip(&sols).enumerate() {
                        assert_eq!(a.iterations, b.iterations, "{ctx} pt {i}: iterations");
                        for j in 0..a.beta.len() {
                            assert_eq!(
                                a.beta[j].to_bits(),
                                b.beta[j].to_bits(),
                                "{ctx} pt {i} j={j}: {} vs {}",
                                a.beta[j],
                                b.beta[j]
                            );
                        }
                    }
                    // The ordinal panic unwound before its point was
                    // published, so the checkpointed prefix is exactly
                    // the last chunk/point boundary before the kill; the
                    // retry meters only the points it newly finished.
                    let prefix =
                        if primal { (k as usize / CTL_CHUNK) * CTL_CHUNK } else { k as usize };
                    let m = svc.metrics();
                    assert_eq!(m.worker_panics(), 1, "{ctx}");
                    assert_eq!(m.jobs_retried(), 1, "{ctx}");
                    assert_eq!(
                        m.resumed_from_checkpoint(),
                        u64::from(prefix > 0),
                        "{ctx}: a non-empty prefix must be resumed, an empty one not"
                    );
                    assert_eq!(
                        m.checkpoints_published(),
                        (points.len() - prefix) as u64,
                        "{ctx}: the resumed prefix must not be re-published"
                    );
                    svc.shutdown();
                }
            }
        }
    }
}

/// Mixed-precision determinism seal: a MixedF32 primal solve must be
/// bit-identical across thread counts under every enabled microkernel —
/// the f32 panel kernels keep the same fixed reduction orders as their
/// f64 twins. (Across *different* kernels only rounding-level agreement
/// holds — FMA fuses — which the agreement seal above already covers.)
#[test]
fn prop_mixed_precision_bit_stable_across_threads_per_kernel() {
    use sven::linalg::{enabled_choices, KernelChoice, Precision};
    use sven::solvers::sven::SvenConfig;
    use sven::util::Parallelism;

    let mut rng = Rng::seed_from(8531);
    // Primal shapes (2p > n) past the parallel fan-out thresholds, dense
    // and sparse, so the threaded f32 panel paths actually engage.
    let xd = Mat::from_fn(220, 230, |_, _| rng.normal());
    let xs = Mat::from_fn(300, 380, |_, _| {
        if rng.bernoulli(0.18) {
            rng.normal()
        } else {
            0.0
        }
    });
    let designs = [Design::from(xd), Design::from(Csr::from_dense(&xs, 0.0))];
    for design in designs {
        let y: Vec<f64> = (0..design.rows()).map(|_| rng.normal()).collect();
        let run = |par: Parallelism, kernel: KernelChoice| -> Vec<f64> {
            let sven = Sven::with_config(
                RustBackend::default(),
                SvenConfig {
                    mode: SvmMode::Primal,
                    parallelism: par,
                    kernel,
                    precision: Precision::MixedF32,
                    ..Default::default()
                },
            );
            let prob = EnProblem::new(design.clone(), y.clone(), 0.7, 0.5);
            sven.solve(&prob).expect("solve").beta
        };
        for kernel in enabled_choices() {
            let serial = run(Parallelism::None, kernel);
            for nt in [2usize, 4] {
                let threaded = run(Parallelism::Fixed(nt), kernel);
                for (j, (a, b)) in serial.iter().zip(&threaded).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "sparse={} kernel={kernel} nt={nt} j={j}: {a} vs {b}",
                        design.is_sparse()
                    );
                }
            }
        }
    }
}
