"""Make the `compile` package importable when pytest runs from the repo
root (`python -m pytest python/tests -q`): the package lives at
`python/compile`, so `python/` must be on sys.path."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
