"""AOT pipeline: HLO-text artifacts are well formed, the manifest matches,
and a lowered artifact round-trips through the XLA client exactly like the
eager program (this is precisely what the rust runtime does via PJRT)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_manifest_and_artifact_files(tmp_path):
    out = str(tmp_path / "artifacts")
    # one tiny bucket per kind to keep the test fast
    old_pb, old_db, old_gb = aot.PRIMAL_BUCKETS, aot.DUAL_BUCKETS, aot.GRAM_BUCKETS
    aot.PRIMAL_BUCKETS, aot.DUAL_BUCKETS, aot.GRAM_BUCKETS = [(16, 8)], [8], [(64, 8)]
    try:
        manifest = aot.build(out, verbose=False)
    finally:
        aot.PRIMAL_BUCKETS, aot.DUAL_BUCKETS, aot.GRAM_BUCKETS = old_pb, old_db, old_gb

    assert len(manifest["artifacts"]) == 3
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["fingerprint"] == manifest["fingerprint"]
    for art in on_disk["artifacts"]:
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), art
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text, f"not HLO text: {art}"


def test_hlo_text_parses_back():
    """The emitted text must parse back into an HloModule with the
    expected entry signature — the same parse the rust runtime performs
    (full load-and-execute coverage lives in rust/tests/runtime_xla.rs,
    since that is the production path)."""
    from jax._src.lib import xla_client as xc

    n, p = 12, 6
    text = aot.lower_primal(n, p)
    module = xc._xla.hlo_module_from_text(text)
    sig = str(module.to_string())
    # 6 parameters: X, y, t, c, mask, w0
    for token in [
        f"f64[{n},{p}]",  # X
        f"f64[{2 * p}]",  # mask / alpha slots
        "ENTRY",
    ]:
        assert token in sig, f"missing {token}"


def test_dual_artifact_parses_back():
    from jax._src.lib import xla_client as xc

    p = 8
    text = aot.lower_dual(p)
    module = xc._xla.hlo_module_from_text(text)
    sig = str(module.to_string())
    assert f"f64[{p},{p}]" in sig  # G0
    assert f"f64[{2 * p}]" in sig  # mask/alpha


def test_fingerprint_stable():
    assert aot._inputs_fingerprint() == aot._inputs_fingerprint()


def test_no_elided_constants():
    """Regression: the default HLO printer elides large constants as
    ``constant({...})``, which parses back as zeros and silently corrupts
    the artifact. Our printer must never emit the elision marker."""
    for text in (aot.lower_primal(16, 8), aot.lower_dual(8), aot.lower_gram(64, 8)):
        assert "constant({...})" not in text
        assert "..." not in text, "elided constant leaked into artifact"
