"""Shape-bucket padding exactness: a problem padded into a larger bucket
with the validity mask must produce the *same* solution as the snug shape.
This is the property the rust runtime's bucket manager relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_enable_x64", True)


def make_problem(n, p, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    X = (X - X.mean(0)) / np.maximum(X.std(0), 1e-12)
    bt = np.zeros(p)
    bt[: min(3, p)] = [1.2, -0.7, 0.4][: min(3, p)]
    y = X @ bt + 0.1 * rng.standard_normal(n)
    y -= y.mean()
    return X, y


def pad_problem(X, y, n_pad, p_pad):
    """Zero-pad the regression problem to (n_pad, p_pad) and build the
    sample mask over 2·p_pad (padded features masked out)."""
    n, p = X.shape
    Xp = np.zeros((n_pad, p_pad))
    Xp[:n, :p] = X
    yp = np.zeros(n_pad)
    yp[:n] = y
    mask = np.zeros(2 * p_pad)
    mask[:p] = 1.0
    mask[p_pad : p_pad + p] = 1.0
    return Xp, yp, mask


def unpad_beta(beta_p, p, p_pad):
    return np.concatenate([beta_p[:p]])


def test_primal_padding_exact():
    n, p = 18, 10
    X, y = make_problem(n, p, 0)
    t, lambda2 = 0.8, 0.3
    snug = np.asarray(model.sven_solve_primal(jnp.array(X), jnp.array(y), t, lambda2))

    n_pad, p_pad = 32, 24
    Xp, yp, mask = pad_problem(X, y, n_pad, p_pad)
    c = jnp.float64(1.0 / (2.0 * lambda2))
    _, alpha, _ = model.svm_primal_program(
        jnp.array(Xp), jnp.array(yp), jnp.float64(t), c,
        jnp.array(mask), jnp.zeros((n_pad,)))
    alpha = np.asarray(alpha)
    # padded sample slots must carry zero dual mass
    assert np.all(alpha[p:p_pad] == 0.0)
    assert np.all(alpha[p_pad + p :] == 0.0)
    beta_padded = np.asarray(model.sven_backmap(jnp.array(alpha), p_pad, t))
    np.testing.assert_allclose(beta_padded[:p], snug, atol=1e-9)
    np.testing.assert_allclose(beta_padded[p:], 0.0, atol=0)


def test_dual_padding_exact():
    n, p = 60, 8
    X, y = make_problem(n, p, 1)
    t, lambda2 = 0.6, 0.4
    snug = np.asarray(model.sven_solve_dual(jnp.array(X), jnp.array(y), t, lambda2))

    n_pad, p_pad = 96, 16
    Xp, yp, mask = pad_problem(X, y, n_pad, p_pad)
    g0, v, yy = model.gram_program(jnp.array(Xp), jnp.array(yp))
    c = jnp.float64(1.0 / (2.0 * lambda2))
    alpha, _ = model.svm_dual_program(
        g0, v, yy, jnp.float64(t), c, jnp.array(mask), jnp.zeros((2 * p_pad,)))
    alpha = np.asarray(alpha)
    assert np.all(alpha[p:p_pad] == 0.0)
    assert np.all(alpha[p_pad + p :] == 0.0)
    beta_padded = np.asarray(model.sven_backmap(jnp.array(alpha), p_pad, t))
    np.testing.assert_allclose(beta_padded[:p], snug, atol=1e-9)


def test_gram_padding_zero_blocks():
    n, p = 20, 6
    X, y = make_problem(n, p, 2)
    Xp, yp, _ = pad_problem(X, y, 40, 12)
    g0, v, yy = model.gram_program(jnp.array(Xp), jnp.array(yp))
    g0 = np.asarray(g0)
    v = np.asarray(v)
    np.testing.assert_allclose(g0[:p, :p], X.T @ X, atol=1e-10)
    np.testing.assert_allclose(g0[p:, :], 0.0, atol=0)
    np.testing.assert_allclose(g0[:, p:], 0.0, atol=0)
    np.testing.assert_allclose(v[:p], X.T @ y, atol=1e-10)
    np.testing.assert_allclose(v[p:], 0.0, atol=0)
    assert float(yy) == np.testing.assert_allclose(float(yy), y @ y, atol=1e-10) or True


def test_n_only_padding_needs_no_mask_change():
    # Padding samples (n) alone is exact with the same full mask.
    n, p = 14, 9
    X, y = make_problem(n, p, 3)
    t, lambda2 = 0.5, 0.2
    snug = np.asarray(model.sven_solve_primal(jnp.array(X), jnp.array(y), t, lambda2))
    Xp = np.zeros((30, p))
    Xp[:n] = X
    yp = np.zeros(30)
    yp[:n] = y
    padded = np.asarray(model.sven_solve_primal(jnp.array(Xp), jnp.array(yp), t, lambda2))
    np.testing.assert_allclose(padded, snug, atol=1e-10)
