"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles, with
hypothesis sweeping shapes and dtypes (the CORE correctness signal of the
compile path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is optional offline; skip this module (not the whole run)
# when it is absent so the remaining kernel/model tests still gate.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import hinge as hinge_k
from compile.kernels import matmul as matmul_k
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

DIMS = st.integers(min_value=1, max_value=70)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def tol_for(dtype):
    return 1e-5 if dtype == jnp.float32 else 1e-11


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([jnp.float32, jnp.float64]))
def test_matmul_matches_ref(m, k, n, seed, dtype):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, (m, k), dtype), rand(rng, (k, n), dtype)
    got = matmul_k.matmul(x, y)
    want = ref.matmul_ref(x, y)
    assert got.shape == want.shape
    assert got.dtype == dtype
    np.testing.assert_allclose(got, want, rtol=tol_for(dtype), atol=tol_for(dtype))


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([jnp.float32, jnp.float64]))
def test_matvec_matches_ref(m, n, seed, dtype):
    rng = np.random.default_rng(seed)
    a, v = rand(rng, (m, n), dtype), rand(rng, (n,), dtype)
    got = matmul_k.matvec(a, v)
    np.testing.assert_allclose(
        got, ref.matvec_ref(a, v), rtol=tol_for(dtype), atol=tol_for(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(n=DIMS, p=DIMS, seed=st.integers(0, 2**31 - 1))
def test_gram_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (n, p), jnp.float64)
    got = matmul_k.gram(x)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)
    # gram output must be symmetric PSD
    np.testing.assert_allclose(got, got.T, rtol=0, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([jnp.float32, jnp.float64]))
def test_hinge_matches_ref(m, seed, dtype):
    rng = np.random.default_rng(seed)
    o = rand(rng, (m,), dtype)
    yhat = jnp.asarray(rng.choice([-1.0, 1.0], m), dtype)
    mask = jnp.asarray(rng.choice([0.0, 1.0], m, p=[0.2, 0.8]), dtype)
    slack, sv, loss = hinge_k.hinge(o, yhat, mask)
    rslack, rsv, rloss = ref.hinge_ref(o, yhat, mask)
    np.testing.assert_allclose(slack, rslack, rtol=tol_for(dtype), atol=tol_for(dtype))
    np.testing.assert_allclose(sv, rsv, rtol=0, atol=0)
    np.testing.assert_allclose(loss, rloss, rtol=1e-4 if dtype == jnp.float32 else 1e-10)


def test_matmul_exact_tile_multiples():
    # shapes that hit the tiled path without padding
    rng = np.random.default_rng(7)
    x = rand(rng, (256, 512), jnp.float64)
    y = rand(rng, (512, 128), jnp.float64)
    np.testing.assert_allclose(
        matmul_k.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-11, atol=1e-11
    )


def test_hinge_padded_entries_are_inert():
    # mask=0 rows contribute nothing regardless of margin values
    o = jnp.array([100.0, -100.0, 0.5])
    yhat = jnp.array([1.0, 1.0, 1.0])
    mask = jnp.array([0.0, 0.0, 1.0])
    slack, sv, loss = hinge_k.hinge(o, yhat, mask)
    assert float(slack[0]) == 0.0 and float(slack[1]) == 0.0
    assert float(loss) == pytest.approx(0.25)


def test_matmul_under_jit_and_grad_free():
    # must be traceable inside jit (artifact requirement)
    rng = np.random.default_rng(8)
    x = rand(rng, (32, 16), jnp.float64)
    y = rand(rng, (16, 8), jnp.float64)
    f = jax.jit(lambda a, b: matmul_k.matmul(a, b).sum())
    assert np.isfinite(float(f(x, y)))
