"""L2 solver correctness: the fixed-shape JAX SVEN programs must solve the
Elastic Net exactly. Ground truth is an independent numpy coordinate
descent (glmnet-style), mirroring the paper's correctness protocol
(glmnet vs SVEN along the path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Independent numpy reference: penalized-form Elastic Net CD
# ---------------------------------------------------------------------------

def cd_elastic_net(X, y, lam, kappa, tol=1e-13, max_epochs=20000):
    """glmnet-convention CD: min 1/(2n)‖Xβ−y‖² + λ(κ|β|₁ + (1−κ)/2‖β‖²)."""
    n, p = X.shape
    beta = np.zeros(p)
    r = y.copy()
    l1, l2 = lam * kappa, lam * (1.0 - kappa)
    colsq = (X ** 2).sum(0) / n
    for _ in range(max_epochs):
        delta = 0.0
        for j in range(p):
            zj = X[:, j] @ r / n + colsq[j] * beta[j]
            bj = np.sign(zj) * max(abs(zj) - l1, 0.0) / (colsq[j] + l2)
            if bj != beta[j]:
                r -= X[:, j] * (bj - beta[j])
                delta = max(delta, (bj - beta[j]) ** 2)
                beta[j] = bj
        if delta < tol:
            break
    return beta


def make_problem(n, p, seed, support=4, snr=5.0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    X = (X - X.mean(0)) / np.maximum(X.std(0), 1e-12)
    bt = np.zeros(p)
    idx = rng.permutation(p)[:support]
    bt[idx] = rng.choice([-1.0, 1.0], support) * (1.0 + rng.random(support))
    signal = X @ bt
    noise = rng.standard_normal(n)
    y = signal + noise * np.linalg.norm(signal) / (snr * np.linalg.norm(noise))
    y -= y.mean()
    return X, y


def grid_point(X, y, kappa=0.5, frac=0.3):
    """One (t, λ₂) setting derived with the paper's protocol."""
    n = X.shape[0]
    lam_max = np.abs(X.T @ y).max() / (n * kappa)
    lam = lam_max * frac
    beta_star = cd_elastic_net(X, y, lam, kappa)
    t = np.abs(beta_star).sum()
    lambda2 = n * lam * (1.0 - kappa)
    return beta_star, t, lambda2


# ---------------------------------------------------------------------------
# Exactness vs the independent CD reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,seed", [(30, 12, 0), (20, 40, 1), (50, 8, 2)])
def test_primal_matches_cd(n, p, seed):
    X, y = make_problem(n, p, seed)
    beta_star, t, lambda2 = grid_point(X, y)
    if t < 1e-10:
        pytest.skip("all-zero reference solution")
    beta = np.asarray(
        model.sven_solve_primal(jnp.array(X), jnp.array(y), float(t), float(lambda2))
    )
    np.testing.assert_allclose(beta, beta_star, atol=5e-5)


@pytest.mark.parametrize("n,p,seed", [(60, 10, 3), (120, 20, 4), (80, 6, 5)])
def test_dual_matches_cd(n, p, seed):
    X, y = make_problem(n, p, seed)
    beta_star, t, lambda2 = grid_point(X, y)
    if t < 1e-10:
        pytest.skip("all-zero reference solution")
    beta = np.asarray(
        model.sven_solve_dual(jnp.array(X), jnp.array(y), float(t), float(lambda2))
    )
    np.testing.assert_allclose(beta, beta_star, atol=5e-5)


@pytest.mark.parametrize("seed", range(4))
def test_primal_dual_agree(seed):
    X, y = make_problem(25, 15, 100 + seed)
    _, t, lambda2 = grid_point(X, y, kappa=0.6, frac=0.25)
    if t < 1e-10:
        pytest.skip("all-zero reference solution")
    bp = np.asarray(model.sven_solve_primal(jnp.array(X), jnp.array(y), float(t), float(lambda2)))
    bd = np.asarray(model.sven_solve_dual(jnp.array(X), jnp.array(y), float(t), float(lambda2)))
    np.testing.assert_allclose(bp, bd, atol=1e-8)


def test_l1_budget_tight():
    X, y = make_problem(30, 20, 200)
    _, t, lambda2 = grid_point(X, y)
    beta = np.asarray(model.sven_solve_primal(jnp.array(X), jnp.array(y), float(t), float(lambda2)))
    assert np.abs(beta).sum() == pytest.approx(t, rel=1e-9)


# ---------------------------------------------------------------------------
# Program building blocks
# ---------------------------------------------------------------------------

def test_xhat_operators_match_explicit():
    rng = np.random.default_rng(9)
    n, p, t = 11, 7, 0.8
    X = rng.standard_normal((n, p))
    y = rng.standard_normal(n)
    Xh = np.vstack([X.T - y[None, :] / t, X.T + y[None, :] / t])  # (2p, n)
    v = rng.standard_normal(n)
    u = rng.standard_normal(2 * p)
    got_mv = np.asarray(model.xhat_matvec(jnp.array(X), jnp.array(y), jnp.float64(t), jnp.array(v)))
    np.testing.assert_allclose(got_mv, Xh @ v, atol=1e-11)
    got_rmv = np.asarray(model.xhat_rmatvec(jnp.array(X), jnp.array(y), jnp.float64(t), jnp.array(u)))
    np.testing.assert_allclose(got_rmv, Xh.T @ u, atol=1e-11)


def test_kernel_matrix_assembly():
    rng = np.random.default_rng(10)
    n, p, t = 9, 5, 1.3
    X = rng.standard_normal((n, p))
    y = rng.standard_normal(n)
    g0 = X.T @ X
    v = X.T @ y
    yy = y @ y
    K = np.asarray(model.assemble_kernel_matrix(
        jnp.array(g0), jnp.array(v), jnp.float64(yy), jnp.float64(t)))
    # naive: columns z_i = yhat_i xhat_i
    Xh = np.vstack([X.T - y[None, :] / t, X.T + y[None, :] / t])
    yhat = np.concatenate([np.ones(p), -np.ones(p)])
    Z = (Xh * yhat[:, None]).T  # n × 2p
    np.testing.assert_allclose(K, Z.T @ Z, atol=1e-10)


def test_gram_program_outputs():
    rng = np.random.default_rng(11)
    X = rng.standard_normal((40, 6))
    y = rng.standard_normal(40)
    g0, v, yy = model.gram_program(jnp.array(X), jnp.array(y))
    np.testing.assert_allclose(np.asarray(g0), X.T @ X, atol=1e-10)
    np.testing.assert_allclose(np.asarray(v), X.T @ y, atol=1e-10)
    assert float(yy) == pytest.approx(y @ y)


def test_dual_warm_start_bad_scale_converges():
    """Regression: a value-based warm start with the wrong dual scaling
    must not stall the projected Newton (the line-search-failure → done
    path); the gradient fallback guarantees progress."""
    X, y = make_problem(60, 8, 700)
    t, lambda2 = 1.2, 1.5
    ref = np.asarray(model.sven_solve_dual(jnp.array(X), jnp.array(y), t, lambda2))
    g0, v, yy = model.gram_program(jnp.array(X), jnp.array(y))
    c = jnp.float64(1.0 / (2 * lambda2))
    p = 8
    # α0 on a β/t scale (what the coordinator's beta_to_warm feeds)
    a0 = np.zeros(2 * p)
    a0[0], a0[p + 1] = 0.9, 0.4
    alpha, _ = model.svm_dual_program(
        g0, v, yy, jnp.float64(t), c, jnp.ones(2 * p), jnp.array(a0))
    beta = np.asarray(model.sven_backmap(alpha, p, t))
    np.testing.assert_allclose(beta, ref, atol=1e-8)


def test_degenerate_backmap_zero_alpha():
    # |α|₁ = 0 (paper footnote 1): back-map must return β = 0, not NaN.
    beta = np.asarray(model.sven_backmap(jnp.zeros(12), 6, 0.5))
    np.testing.assert_allclose(beta, 0.0, atol=0)
    assert np.all(np.isfinite(beta))


def test_huge_budget_still_finite():
    # t far beyond the ridge norm: the solve must stay finite and respect
    # |β|₁ ≤ t (the coordinator flags this regime as SlackBudget).
    X, y = make_problem(15, 6, 300)
    beta = np.asarray(model.sven_solve_primal(jnp.array(X), jnp.array(y), 1e6, 0.5))
    assert np.all(np.isfinite(beta))
    assert np.abs(beta).sum() <= 1e6 * (1 + 1e-9)


def test_warm_start_path_consistency():
    # Solving with a warm start from a neighbouring path point must land
    # on the same solution (artifact input `w0`/`alpha0` correctness).
    X, y = make_problem(26, 13, 400)
    _, t, lambda2 = grid_point(X, y, frac=0.3)
    n, p = X.shape
    Xj, yj = jnp.array(X), jnp.array(y)
    mask = jnp.ones((2 * p,))
    c = jnp.float64(1.0 / (2.0 * lambda2))
    w_a, alpha_a, _ = model.svm_primal_program(
        Xj, yj, jnp.float64(t), c, mask, jnp.zeros((n,)))
    # warm start at a nearby budget, then resolve at t
    w_b, _, _ = model.svm_primal_program(
        Xj, yj, jnp.float64(t * 0.9), c, mask, jnp.zeros((n,)))
    w_c, alpha_c, _ = model.svm_primal_program(
        Xj, yj, jnp.float64(t), c, mask, w_b)
    beta_a = np.asarray(model.sven_backmap(alpha_a, p, t))
    beta_c = np.asarray(model.sven_backmap(alpha_c, p, t))
    np.testing.assert_allclose(beta_a, beta_c, atol=1e-7)
