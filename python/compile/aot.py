"""AOT pipeline: lower the L2 programs to HLO *text* per shape bucket and
write ``artifacts/manifest.json`` for the rust runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape buckets: one artifact per (program, bucket). The rust runtime pads a
problem up to the smallest covering bucket and passes the validity mask,
which makes padding exact (tests/test_padding.py, rust/tests/padding.rs).

Usage: ``python -m compile.aot --out ../artifacts`` (idempotent; the
Makefile skips it when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

from . import model

jax.config.update("jax_enable_x64", True)

F64 = jnp.float64

# ---------------------------------------------------------------------------
# Bucket tables. (n, p) are the *regression* problem dims; the SVM sees
# m = 2p samples with d = n features. Chosen to cover the 12 dataset
# profiles plus test/example sizes; the runtime picks the smallest cover.
# ---------------------------------------------------------------------------

# Primal buckets (2p > n regime; Figure 2 profiles + small sizes).
PRIMAL_BUCKETS: list[tuple[int, int]] = [
    (32, 64),
    (128, 512),
    (128, 2048),
    # quick-bench shapes (scale factor 0.25 of the profiles): tight
    # buckets keep padding waste low where absolute times are smallest
    (64, 1536),
    (64, 2560),
    (128, 4096),
    (128, 6144),
    (256, 6144),
    # full-profile shapes
    (128, 12288),
    (256, 12288),
    (512, 20480),
    (1024, 24576),
]

# Dual buckets by p (n ≥ 2p regime; Figure 3 profiles + small sizes).
DUAL_BUCKETS: list[int] = [16, 64, 128, 512, 1024]

# Gram buckets (n, p) for the dual-mode preprocessing.
GRAM_BUCKETS: list[tuple[int, int]] = [
    (256, 16),
    (2048, 64),
    (8192, 128),
    (65536, 128),
    (40960, 512),
    (30720, 1024),
    (20480, 1024),
]


def _to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    CRITICAL: print with ``print_large_constants=True``. The default HLO
    printer elides arrays beyond a few elements as ``constant({...})``,
    which the consuming parser silently reads back as *zeros* — the
    artifact would type-check and run but compute garbage. (Found the hard
    way; regression-tested by test_aot.py::test_no_elided_constants.)
    """
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The consumer is xla_extension 0.5.1, whose parser predates newer
    # metadata attributes (source_end_line etc.) — strip metadata.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _spec(shape, dtype=F64):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_primal(n: int, p: int) -> str:
    fn = jax.jit(model.svm_primal_program)
    lowered = fn.lower(
        _spec((n, p)),      # X
        _spec((n,)),        # y
        _spec(()),          # t
        _spec(()),          # c
        _spec((2 * p,)),    # mask
        _spec((n,)),        # w0
    )
    return _to_hlo_text(lowered)


def lower_dual(p: int) -> str:
    fn = jax.jit(model.svm_dual_program)
    lowered = fn.lower(
        _spec((p, p)),      # G0
        _spec((p,)),        # v
        _spec(()),          # yy
        _spec(()),          # t
        _spec(()),          # c
        _spec((2 * p,)),    # mask
        _spec((2 * p,)),    # alpha0
    )
    return _to_hlo_text(lowered)


def lower_gram(n: int, p: int) -> str:
    fn = jax.jit(model.gram_program)
    lowered = fn.lower(_spec((n, p)), _spec((n,)))
    return _to_hlo_text(lowered)


def _inputs_fingerprint() -> str:
    """Hash of the compile-path sources, for idempotent rebuilds."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, *, only: str | None = None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "format": 1,
        "fingerprint": _inputs_fingerprint(),
        "dtype": "f64",
        "artifacts": [],
    }

    def emit(name: str, kind: str, text: str, meta: dict):
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {"name": name, "kind": kind, "file": fname, **meta}
        )
        if verbose:
            print(f"  {name}: {len(text) / 1024:.0f} KiB", flush=True)

    if only in (None, "primal"):
        for n, p in PRIMAL_BUCKETS:
            emit(
                f"svm_primal_n{n}_p{p}",
                "primal",
                lower_primal(n, p),
                {"n": n, "p": p},
            )
    if only in (None, "dual"):
        for p in DUAL_BUCKETS:
            emit(f"svm_dual_p{p}", "dual", lower_dual(p), {"p": p})
    if only in (None, "gram"):
        for n, p in GRAM_BUCKETS:
            emit(f"gram_n{n}_p{p}", "gram", lower_gram(n, p), {"n": n, "p": p})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", choices=["primal", "dual", "gram"], default=None)
    ap.add_argument(
        "--force", action="store_true", help="rebuild even if up to date"
    )
    args = ap.parse_args()

    manifest_path = os.path.join(args.out, "manifest.json")
    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == _inputs_fingerprint():
                print("artifacts up to date; skipping (use --force to rebuild)")
                return
        except (json.JSONDecodeError, OSError):
            pass
    build(args.out, only=args.only)


if __name__ == "__main__":
    sys.exit(main())
