"""Pallas kernels (L1) for the SVEN SVM solve."""
from . import hinge, matmul, ref  # noqa: F401
