"""L1 Pallas kernel: fused squared-hinge pass.

Given margins ``o = X̂w`` (or ``Kγ`` in the kernelized mode), labels and a
validity mask, one sweep produces the slack vector, the support-vector
mask and the loss contribution — the elementwise stage between the two
matmuls of every Newton/CG step. On TPU this is a VPU map over
(8, 128)-aligned tiles; a single fused pass instead of three separate
elementwise ops saves two HBM round-trips of the m-length vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Interpret/CPU schedule: one grid step for every bucket in this repo (a
# real-TPU build would tile at 8x128 VPU lanes - see matmul.py's schedule
# note).
BLOCK = 131072


def _hinge_kernel(o_ref, yhat_ref, mask_ref, slack_ref, sv_ref, losspart_ref):
    o = o_ref[...]
    yhat = yhat_ref[...]
    mask = mask_ref[...]
    raw = 1.0 - yhat * o
    slack = jnp.maximum(raw, 0.0) * mask
    slack_ref[...] = slack
    sv_ref[...] = jnp.where(slack > 0.0, mask, jnp.zeros_like(mask))
    losspart_ref[...] = slack * slack


@jax.jit
def hinge(o: jax.Array, yhat: jax.Array, mask: jax.Array):
    """Fused hinge pass.

    Returns ``(slack, sv_mask, loss)`` with
    ``slack_i = mask_i·max(0, 1 − ŷᵢ oᵢ)``, ``sv_mask`` the indicator of
    active (support-vector) samples, and ``loss = Σ slackᵢ²``.
    """
    (m,) = o.shape
    block = min(BLOCK, m)
    mp = -(-m // block) * block
    pad = mp - m
    if pad:
        o = jnp.pad(o, (0, pad))
        yhat = jnp.pad(yhat, (0, pad))
        mask = jnp.pad(mask, (0, pad))  # zero mask ⇒ padded entries inert
    slack, sv, losspart = pl.pallas_call(
        _hinge_kernel,
        grid=(mp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), o.dtype),
            jax.ShapeDtypeStruct((mp,), o.dtype),
            jax.ShapeDtypeStruct((mp,), o.dtype),
        ],
        interpret=True,
    )(o, yhat, mask)
    return slack[:m], sv[:m], jnp.sum(losspart)
