"""L1 Pallas kernels: tiled matmul / matvec — the compute hot-spot of the
SVEN SVM solve (gram matrices and Newton-CG matrix products).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper offloads
these products to CUBLAS GEMM on a GTX TITAN; on TPU the same role is
played by MXU-tiled matmuls. The BlockSpec schedule below expresses the
HBM→VMEM streaming the paper got from CUDA threadblocks: (bm × bk) and
(bk × bn) tiles stream through VMEM while an output tile is revisited
across the k grid dimension and accumulated in place.

All kernels run ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the AOT artifact is runnable from rust. Real-TPU tile-size analysis lives
in EXPERIMENTS.md §Perf-L1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# --- Tile schedules --------------------------------------------------------
#
# TPU (Mosaic) schedule: 128 matches both the MXU systolic dimension and
# the VPU lane count; the k tile is larger to amortize the accumulation
# loop. VMEM per step ≈ (BM·BK + BK·BN + BM·BN)·4B ≈ 0.4 MiB — 16 tiles
# double-buffered fit the ~16 MiB VMEM budget. This is the schedule a real
# TPU build would use and the one analyzed in EXPERIMENTS.md §Perf-L1.
TPU_BM = 128
TPU_BN = 128
TPU_BK = 256

# Interpret/CPU schedule: the AOT artifacts in this repo execute through
# the PJRT *CPU* client, where every grid step lowers to a
# while-loop iteration (dynamic-slice + dot + update-slice). Small tiles
# fragment a single GEMM into thousands of tiny serial ops — measured 40×
# slowdown on the (128, 2048)-bucket solve (EXPERIMENTS.md §Perf-L1). The
# CPU schedule therefore uses monolithic tiles: one grid step for every
# shape this repo compiles, turning the kernel into a single fused dot.
BM = 16384
BN = 16384
BK = 16384


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (bm × bn) output tile; accumulates over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr = rows - x.shape[0]
    pc = cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_to(v: int, b: int) -> int:
    return -(-v // b) * b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
) -> jax.Array:
    """``x @ y`` via the Pallas tiled kernel (any shapes; zero-padded to
    tile multiples internally, which is exact for matmul)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"shape mismatch {x.shape} @ {y.shape}"
    # Clamp tiles to the problem so the grid is never empty and matvecs
    # (n = 1) carry no lane padding in interpret mode.
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matvec(a: jax.Array, v: jax.Array) -> jax.Array:
    """``a @ v`` for a 1-D ``v`` through the tiled kernel."""
    return matmul(a, v[:, None])[:, 0]


def gram(x: jax.Array) -> jax.Array:
    """``xᵀ x`` — the t-independent block of the SVEN kernel matrix."""
    return matmul(x.T, x)
