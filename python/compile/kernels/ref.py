"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth
pytest checks every kernel against (shapes/dtypes swept by hypothesis)."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def matvec_ref(a, v):
    return a @ v


def gram_ref(x):
    return x.T @ x


def hinge_ref(o, yhat, mask):
    slack = jnp.maximum(1.0 - yhat * o, 0.0) * mask
    sv = jnp.where(slack > 0.0, mask, jnp.zeros_like(mask))
    return slack, sv, jnp.sum(slack * slack)
