"""L2: the SVEN SVM solve as fixed-shape JAX programs (build-time only).

Three programs are AOT-lowered per shape bucket (see ``aot.py``):

``gram_program(X, y)``
    The t-independent pieces of the SVEN kernel matrix: ``G₀ = XᵀX``
    (Pallas tiled matmul), ``v = Xᵀy`` and ``yy = yᵀy``. Computed once per
    data set in the n ≫ p regime and cached by the rust coordinator across
    all 40 path points — the reason the paper's Figure-3 SVEN timings are
    flat in t.

``svm_primal_program(X, y, t, c, mask, w0)``
    Chapelle primal Newton-CG on the *implicit* reduction: the SVM design
    ``X̂ = [Xᵀ − 1yᵀ/t ; Xᵀ + 1yᵀ/t]`` is never materialized; its matvecs
    are one X product plus a rank-one correction. Used when 2p > n.

``svm_dual_program(G0, v, yy, t, c, mask, alpha0)``
    Projected Newton (masked-CG inner solves) on the non-negative dual QP
    over the kernel matrix K(t) assembled on the fly from the cached gram
    pieces. Used when n ≥ 2p.

All programs take a `mask ∈ {0,1}^{2p}` so problems padded into a shape
bucket are solved *exactly* (padded features contribute nothing — see
``tests/test_padding.py``). Scalars (t, c) are 0-d f64 inputs, so one
artifact serves every path point of every data set that fits its bucket.

Python never runs at serving time: these functions exist to be lowered to
HLO text by ``aot.py`` and executed from rust via PJRT.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import hinge as hinge_k
from .kernels import matmul as matmul_k

jax.config.update("jax_enable_x64", True)

# Iteration caps (static; while_loops exit early on convergence).
NEWTON_MAX = 60
CG_MAX = 400
LINESEARCH_MAX = 30
NEWTON_TOL = 1e-10
CG_TOL = 1e-12
KKT_TOL = 1e-9


# --------------------------------------------------------------------------
# Implicit reduction operators
# --------------------------------------------------------------------------

def reduction_labels(p: int, dtype=jnp.float64) -> jax.Array:
    """ŷ = (+1 … +1, −1 … −1).

    Built from an iota rather than a literal constant so the AOT HLO text
    stays small (a 2p-element f64 constant would be printed inline — see
    the large-constant note in ``aot._to_hlo_text``).
    """
    idx = jnp.arange(2 * p)
    return jnp.where(idx < p, jnp.ones((), dtype), -jnp.ones((), dtype))


def xhat_matvec(x: jax.Array, y: jax.Array, t: jax.Array, w: jax.Array) -> jax.Array:
    """``X̂ @ w`` for the SVEN construction, implicit form.

    ``X̂ = [Xᵀ − 1yᵀ/t ; Xᵀ + 1yᵀ/t]`` (2p × n), so
    ``X̂w = concat(Xᵀw − (yᵀw/t)·1, Xᵀw + (yᵀw/t)·1)``.
    """
    xtw = matmul_k.matvec(x.T, w)  # (p,) — Pallas tiled
    shift = jnp.dot(y, w) / t
    return jnp.concatenate([xtw - shift, xtw + shift])


def xhat_rmatvec(x: jax.Array, y: jax.Array, t: jax.Array, u: jax.Array) -> jax.Array:
    """``X̂ᵀ @ u = X(u₁ + u₂) + ((Σu₂ − Σu₁)/t)·y``."""
    p = x.shape[1]
    u1, u2 = u[:p], u[p:]
    out = matmul_k.matvec(x, u1 + u2)  # (n,)
    coeff = (jnp.sum(u2) - jnp.sum(u1)) / t
    return out + coeff * y


# --------------------------------------------------------------------------
# Matrix-free conjugate gradients (shared by both programs)
# --------------------------------------------------------------------------

class _CgState(NamedTuple):
    x: jax.Array
    r: jax.Array
    pdir: jax.Array
    rs: jax.Array
    it: jax.Array
    done: jax.Array


def _cg(operator, b: jax.Array, x0: jax.Array, max_iter: int, tol: float):
    """Solve ``operator(x) = b`` from ``x0``; returns (x, iters)."""
    bnorm2 = jnp.dot(b, b)
    stop2 = (tol * tol) * jnp.maximum(bnorm2, 1e-300)

    r0 = b - operator(x0)
    state = _CgState(
        x=x0,
        r=r0,
        pdir=r0,
        rs=jnp.dot(r0, r0),
        it=jnp.zeros((), jnp.int32),
        done=jnp.dot(r0, r0) <= stop2,
    )

    def cond(s: _CgState):
        return jnp.logical_and(~s.done, s.it < max_iter)

    def body(s: _CgState):
        ap = operator(s.pdir)
        pap = jnp.dot(s.pdir, ap)
        # Guard zero-curvature directions (padded/masked subspace).
        alpha = jnp.where(pap > 0.0, s.rs / jnp.maximum(pap, 1e-300), 0.0)
        x = s.x + alpha * s.pdir
        r = s.r - alpha * ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.maximum(s.rs, 1e-300)
        pdir = r + beta * s.pdir
        done = jnp.logical_or(rs_new <= stop2, pap <= 0.0)
        return _CgState(x, r, pdir, rs_new, s.it + 1, done)

    out = jax.lax.while_loop(cond, body, state)
    return out.x, out.it


# --------------------------------------------------------------------------
# Primal Newton-CG (2p > n)
# --------------------------------------------------------------------------

class _NewtonState(NamedTuple):
    w: jax.Array
    obj: jax.Array
    newton_it: jax.Array
    cg_total: jax.Array
    done: jax.Array


def svm_primal_program(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    c: jax.Array,
    mask: jax.Array,
    w0: jax.Array,
):
    """Primal squared-hinge Newton-CG on the implicit reduction.

    Returns ``(w, alpha, iters)`` — α recovered as ``2C·slack`` at the
    final iterate (any positive rescaling cancels in the SVEN back-map).
    """
    n, p = x.shape
    yhat = reduction_labels(p, x.dtype)

    def eval_at(w):
        o = xhat_matvec(x, y, t, w)
        slack, sv, loss = hinge_k.hinge(o, yhat, mask)
        obj = 0.5 * jnp.dot(w, w) + c * loss
        return o, slack, sv, obj

    def gradient(w, slack):
        ys = yhat * slack  # slack already mask-gated by the hinge kernel
        return w - 2.0 * c * xhat_rmatvec(x, y, t, ys)

    def newton_matrix(sv):
        """Explicit Hessian ``H = I + 2C·X̂ᵀ diag(sv) X̂`` via the rank-one
        reduction structure (Chapelle 2007 §4 — the paper's GPU hot-spot).

        With x̂ᵢ = cⱼ ∓ u (u = y/t, cⱼ = column j of X):
        ``X̂ᵀDX̂ = X·diag(s₁+s₂)·Xᵀ + (X(s₂−s₁))uᵀ + u(X(s₂−s₁))ᵀ
                 + Σ(s₁+s₂)·uuᵀ`` — one n×p × p×n GEMM instead of a CG
        loop of serial matvecs (the GEMM is what parallel BLAS — CUBLAS in
        the paper, Eigen under PJRT-CPU here — executes at full width).
        """
        s1, s2 = sv[:p], sv[p:]
        w1 = s1 + s2
        w2 = s2 - s1
        u = y / t
        xw = x * w1[None, :]
        m_core = matmul_k.matmul(xw, x.T)  # Pallas tiled GEMM (n × n)
        xw2 = matmul_k.matvec(x, w2)
        h = m_core + jnp.outer(xw2, u) + jnp.outer(u, xw2) + jnp.sum(w1) * jnp.outer(u, u)
        return jnp.eye(n, dtype=x.dtype) + 2.0 * c * h

    def body(s: _NewtonState):
        _, slack, sv, _ = eval_at(s.w)
        grad = gradient(s.w, slack)

        # LAPACK solves lower to custom-calls the consuming xla_extension
        # (0.5.1) cannot execute, so the SPD system is solved by CG on the
        # *explicit* n×n Hessian — each iteration is one n² gemv instead
        # of the 2·n·p implicit product, a ~2p/n flop reduction on the
        # p ≫ n problems this program serves.
        h = newton_matrix(sv)
        delta, cg_it = _cg(lambda vv: h @ vv, -grad, jnp.zeros_like(s.w), CG_MAX, CG_TOL)

        # Backtracking line search on the true objective.
        def ls_cond(ls):
            step, _, accepted, halvings = ls
            return jnp.logical_and(~accepted, halvings < LINESEARCH_MAX)

        def ls_body(ls):
            step, _, _, halvings = ls
            w_try = s.w + step * delta
            _, _, _, obj_try = eval_at(w_try)
            ok = obj_try <= s.obj + 1e-12 * jnp.abs(s.obj)
            return (
                jnp.where(ok, step, step * 0.5),
                jnp.where(ok, obj_try, s.obj),
                ok,
                halvings + 1,
            )

        step, obj_new, accepted, _ = jax.lax.while_loop(
            ls_cond,
            ls_body,
            (
                jnp.ones((), x.dtype),
                s.obj,
                jnp.zeros((), bool),
                jnp.zeros((), jnp.int32),
            ),
        )
        w_new = jnp.where(accepted, s.w + step * delta, s.w)
        # Converged when the gradient is tiny or no step was accepted.
        _, slack_new, _, _ = eval_at(w_new)
        grad_new = gradient(w_new, slack_new)
        gnorm = jnp.sqrt(jnp.dot(grad_new, grad_new) / n)
        done = jnp.logical_or(
            gnorm <= NEWTON_TOL * (1.0 + jnp.abs(obj_new)), ~accepted
        )
        return _NewtonState(
            w_new, obj_new, s.newton_it + 1, s.cg_total + cg_it, done
        )

    def cond(s: _NewtonState):
        return jnp.logical_and(~s.done, s.newton_it < NEWTON_MAX)

    _, _, _, obj0 = eval_at(w0)
    init = _NewtonState(
        w0,
        obj0,
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    out = jax.lax.while_loop(cond, body, init)

    _, slack, _, _ = eval_at(out.w)
    alpha = 2.0 * c * slack
    return out.w, alpha, out.newton_it.astype(jnp.float64)


# --------------------------------------------------------------------------
# Dual projected Newton over the kernel matrix (n ≥ 2p)
# --------------------------------------------------------------------------

def assemble_kernel_matrix(
    g0: jax.Array, v: jax.Array, yy: jax.Array, t: jax.Array
) -> jax.Array:
    """K(t) = ẐᵀẐ from the t-independent gram pieces (DESIGN.md §2):

    ```
    K = [  G₁₁  −G₁₂ ]    G₁₁ = G₀ − s(v1ᵀ+1vᵀ) + s²·yy
        [ −G₁₂ᵀ  G₂₂ ]    G₂₂ = G₀ + s(v1ᵀ+1vᵀ) + s²·yy
                          G₁₂ = G₀ + s·v1ᵀ − s·1vᵀ − s²·yy
    ```
    """
    s = 1.0 / t
    s2c = s * s * yy
    vs = s * v
    sum_vv = vs[:, None] + vs[None, :]
    diff_vv = vs[:, None] - vs[None, :]
    g11 = g0 - sum_vv + s2c
    g22 = g0 + sum_vv + s2c
    g12 = g0 + diff_vv - s2c
    top = jnp.concatenate([g11, -g12], axis=1)
    bot = jnp.concatenate([-g12.T, g22], axis=1)
    return jnp.concatenate([top, bot], axis=0)


class _DualState(NamedTuple):
    alpha: jax.Array
    free: jax.Array
    it: jax.Array
    done: jax.Array


# Pivot cap for the dual active set: one pivot per support change, so the
# bound is the working-set size, not a Newton-style constant.
DUAL_MAX = 500


def svm_dual_program(
    g0: jax.Array,
    v: jax.Array,
    yy: jax.Array,
    t: jax.Array,
    c: jax.Array,
    mask: jax.Array,
    alpha0: jax.Array,
):
    """Active-set solve of ``min_{α≥0} αᵀKα + ‖α‖²/(2C) − 2·1ᵀα``
    (Lawson–Hanson NNLS structure, matching the rust backend).

    The free set is *state*, not recomputed per iteration: each pivot
    either (a) solves the equality-constrained subproblem on F by masked
    CG and — if feasible — adds the single most-violating bound variable,
    or (b) clips along the segment to the infeasible candidate and drops
    the blocking variables. A plain projected Newton zigzags on this QP
    (the twin columns ẑ_j⁺ ≈ −ẑ_j⁻ make the full-set system near-singular
    and the clipped direction poor); the stateful pivot rule converges in
    O(support) iterations instead.
    """
    k = assemble_kernel_matrix(g0, v, yy, t)
    m = k.shape[0]
    big = jnp.asarray(1e300, k.dtype)

    def kdot(a):
        return matmul_k.matvec(k, a)  # (m,) — Pallas tiled

    def grad(a):
        return 2.0 * kdot(a) + a / c - 2.0

    # Hessian of the dual QP, built once per (t, C); each pivot solves a
    # masked system directly (LAPACK-threaded Cholesky beats a loop of
    # serial K·v gemvs on the CPU PJRT backend by a wide margin).
    h_full = 2.0 * k + jnp.eye(m, dtype=k.dtype) / c

    def body(s: _DualState):
        # Subproblem on F: (2K + I/C)_FF · cand_F = 2·1_F with the
        # complement forced to the identity so the system stays SPD.
        free = s.free
        ff = jnp.outer(free, free)
        # CG, not LAPACK: custom-call-free HLO (see the primal's note).
        h_masked = h_full * ff + jnp.diag(1.0 - free)
        cand, _ = _cg(
            lambda vv: h_masked @ vv, 2.0 * free, s.alpha * free, CG_MAX, CG_TOL
        )
        cand = cand * free

        feasible = jnp.min(jnp.where(free > 0.0, cand, big)) >= -1e-14

        # --- feasible branch: accept candidate, add worst violator -------
        def accept(_):
            a_new = jnp.maximum(cand, 0.0) * mask
            g_new = grad(a_new)
            gscale = 1.0 + jnp.max(jnp.abs(g_new * mask))
            bound = mask * (1.0 - free)
            viol = jnp.maximum(-g_new, 0.0) * bound
            worst = jnp.argmax(viol)
            has_viol = viol[worst] > KKT_TOL * gscale
            free_new = jnp.where(
                has_viol, free.at[worst].set(1.0), free
            )
            return a_new, free_new, ~has_viol

        # --- infeasible branch: clip along segment, drop blockers --------
        def clip(_):
            neg = jnp.logical_and(free > 0.0, cand < -1e-14)
            denom = jnp.maximum(s.alpha - cand, 1e-300)
            ratios = jnp.where(neg, s.alpha / denom, big)
            theta = jnp.minimum(jnp.min(ratios), 1.0)
            a_new = jnp.maximum(s.alpha + theta * (cand - s.alpha), 0.0) * free
            drop = jnp.logical_and(neg, a_new <= 1e-14)
            free_new = jnp.where(drop, 0.0, free)
            return a_new * mask, free_new, jnp.zeros((), bool)

        a_new, free_new, done = jax.lax.cond(feasible, accept, clip, operand=None)
        # Never let the free set go completely empty while the gradient
        # still descends somewhere (e.g. θ = 0 clip on a zero iterate).
        g_cur = grad(a_new)
        empty = jnp.sum(free_new) == 0.0
        seed = jnp.argmin(jnp.where(mask > 0.0, g_cur, big))
        free_new = jnp.where(empty, free_new.at[seed].set(1.0), free_new)
        return _DualState(a_new, free_new, s.it + 1, done)

    def cond(s: _DualState):
        return jnp.logical_and(~s.done, s.it < DUAL_MAX)

    # Warm start seeds the free set (values are re-solved, matching the
    # rust backend — value-based warm starts with the wrong dual scale are
    # what stalled the previous projected-Newton formulation).
    g0_grad = -2.0 * jnp.ones((m,), k.dtype)  # gradient at α = 0
    seed0 = jnp.argmin(jnp.where(mask > 0.0, g0_grad, big))
    free_init = jnp.where(
        jnp.sum((alpha0 > 0.0) * mask) > 0.0,
        (alpha0 > 0.0).astype(k.dtype) * mask,
        jnp.zeros((m,), k.dtype).at[seed0].set(1.0) * mask,
    )
    init = _DualState(
        jnp.zeros((m,), k.dtype),
        free_init,
        jnp.zeros((), jnp.int32),
        jnp.zeros((), bool),
    )
    out = jax.lax.while_loop(cond, body, init)
    return out.alpha, out.it.astype(jnp.float64)


# --------------------------------------------------------------------------
# Gram program (dual-mode preprocessing, cached across path points)
# --------------------------------------------------------------------------

def gram_program(x: jax.Array, y: jax.Array):
    """``(G₀, v, yy) = (XᵀX, Xᵀy, yᵀy)`` — Pallas tiled gram."""
    g0 = matmul_k.gram(x)
    v = matmul_k.matvec(x.T, y)
    yy = jnp.dot(y, y)
    return g0, v, yy


# --------------------------------------------------------------------------
# Reference solvers for pytest (not exported as artifacts)
# --------------------------------------------------------------------------

def sven_backmap(alpha: jax.Array, p: int, t) -> jax.Array:
    """β = t·(α⁺ − α⁻)/Σα (paper Algorithm 1, line 11)."""
    total = jnp.sum(alpha)
    scale = jnp.where(total > 1e-12, t / jnp.maximum(total, 1e-300), 0.0)
    return scale * (alpha[:p] - alpha[p:])


def sven_solve_primal(x, y, t, lambda2, mask=None, w0=None):
    """End-to-end SVEN via the primal program (testing convenience)."""
    n, p = x.shape
    if mask is None:
        mask = jnp.ones((2 * p,), x.dtype)
    if w0 is None:
        w0 = jnp.zeros((n,), x.dtype)
    c = jnp.asarray(1.0 / (2.0 * max(lambda2, 5e-7)), x.dtype)
    _, alpha, _ = svm_primal_program(x, y, jnp.asarray(t, x.dtype), c, mask, w0)
    return sven_backmap(alpha, p, t)


def sven_solve_dual(x, y, t, lambda2, mask=None, alpha0=None):
    """End-to-end SVEN via gram + dual programs (testing convenience)."""
    _, p = x.shape
    if mask is None:
        mask = jnp.ones((2 * p,), x.dtype)
    if alpha0 is None:
        alpha0 = jnp.zeros((2 * p,), x.dtype)
    g0, v, yy = gram_program(x, y)
    c = jnp.asarray(1.0 / (2.0 * max(lambda2, 5e-7)), x.dtype)
    alpha, _ = svm_dual_program(
        g0, v, yy, jnp.asarray(t, x.dtype), c, mask, alpha0
    )
    return sven_backmap(alpha, p, t)
