//! End-to-end system driver — proves all three layers compose:
//!
//!   L1/L2  AOT artifacts (Pallas kernels inside JAX Newton solvers,
//!          lowered to HLO text by `make artifacts`)
//!   runtime PJRT CPU client loading + executing those artifacts
//!   L3      the rust coordinator: worker pool, queue, metrics
//!
//! Workload: two data-set profiles (one p ≫ n → primal artifacts, one
//! n ≫ p → dual+gram artifacts), a 40-point evaluation grid each (the
//! paper's protocol), submitted as concurrent jobs against both the XLA
//! and rust backends. Reports correctness vs the glmnet reference and
//! service latency/throughput percentiles.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::sync::Arc;
use sven::coordinator::{
    BackendChoice, PathRunner, PathRunnerConfig, Service, ServiceConfig,
};
use sven::data::SynthSpec;
use sven::solvers::glmnet::PathSettings;
use sven::util::{fmt_duration, Timer};

fn main() -> anyhow::Result<()> {
    // --- workload: one dataset per regime (sized to the test buckets) ---
    let wide = sven::data::synth_regression(&SynthSpec {
        name: "genomics-like (p>>n)".into(),
        n: 100,
        p: 1500,
        support: 20,
        rho: 0.5,
        snr: 3.0,
        seed: 11,
        ..Default::default()
    });
    let tall = sven::data::synth_regression(&SynthSpec {
        name: "sensor-like (n>>p)".into(),
        n: 1500,
        p: 60,
        support: 12,
        rho: 0.6,
        snr: 3.0,
        seed: 12,
        ..Default::default()
    });

    let runner = PathRunner::new(PathRunnerConfig {
        grid: 40,
        path: PathSettings { num_lambda: 100, ..Default::default() },
        ..Default::default()
    });

    let service = Service::start(ServiceConfig::default());
    let mut total_jobs = 0usize;
    let wall = Timer::start();

    let mut receivers = Vec::new();
    let mut path_jobs = Vec::new();
    for (ds_id, data) in [(1u64, &wide), (2u64, &tall)] {
        let grid = runner.derive_grid(data);
        println!(
            "dataset {:<20} n={:<5} p={:<5} grid={} settings",
            data.name,
            data.n(),
            data.p(),
            grid.len()
        );
        let x = Arc::new(sven::linalg::Design::from(data.x.clone()));
        let y = Arc::new(data.y.clone());
        for (i, pt) in grid.iter().enumerate() {
            for backend in [BackendChoice::Xla, BackendChoice::Rust] {
                let rx = service.submit_point(
                    ds_id,
                    x.clone(),
                    y.clone(),
                    pt.t,
                    pt.lambda2.max(1e-6),
                    backend,
                )?;
                receivers.push((data.name.clone(), i, pt.beta.clone(), backend, rx));
                total_jobs += 1;
            }
        }
        // The same grid once more as a single path job: one request, one
        // shared preparation, warm-start chaining on a worker.
        path_jobs.push((
            data.name.clone(),
            grid.clone(),
            service.submit_path(
                ds_id,
                x.clone(),
                y.clone(),
                runner.grid_points(&grid),
                BackendChoice::Rust,
            )?,
        ));
    }
    println!("\nsubmitted {total_jobs} point jobs + {} path jobs\n", path_jobs.len());

    // --- collect, check correctness against the glmnet reference ---
    let mut ok = 0usize;
    let mut failed = 0usize;
    let mut max_dev = 0.0f64;
    let mut xla_seconds = Vec::new();
    let mut rust_seconds = Vec::new();
    for (ds, _i, beta_ref, backend, rx) in receivers {
        let outcome = rx.recv()?;
        match outcome.result.map(|r| r.expect_point()) {
            Ok(sol) => {
                let dev = sol
                    .beta
                    .iter()
                    .zip(&beta_ref)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f64, f64::max);
                max_dev = max_dev.max(dev);
                if dev > 1e-3 {
                    eprintln!("WARN {ds} dev {dev:.2e} via {backend:?}");
                }
                match backend {
                    BackendChoice::Xla => xla_seconds.push(sol.seconds),
                    BackendChoice::Rust => rust_seconds.push(sol.seconds),
                }
                ok += 1;
            }
            Err(e) => {
                eprintln!("job failed via {backend:?}: {e}");
                failed += 1;
            }
        }
    }
    // --- path jobs: per-point deviation against the same references ---
    for (ds, grid, rx) in path_jobs {
        let outcome = rx.recv()?;
        match outcome.result {
            Ok(r) => {
                let sols = r.expect_path();
                assert_eq!(sols.len(), grid.len());
                for (pt, sol) in grid.iter().zip(&sols) {
                    let dev = sol
                        .beta
                        .iter()
                        .zip(&pt.beta)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f64, f64::max);
                    max_dev = max_dev.max(dev);
                }
                ok += 1;
            }
            Err(e) => {
                eprintln!("path job failed on {ds}: {e}");
                failed += 1;
            }
        }
    }
    let wall_s = wall.elapsed();

    println!("--- results ---------------------------------------------");
    println!("jobs ok={ok} failed={failed} wall={}", fmt_duration(wall_s));
    println!("throughput: {:.1} solves/s", ok as f64 / wall_s);
    println!("correctness: max |beta − beta_glmnet| = {max_dev:.2e} over all jobs");
    let summarize = |name: &str, xs: &[f64]| {
        if xs.is_empty() {
            return;
        }
        let s = sven::util::Summary::from(xs.to_vec());
        println!(
            "{name:<12} solve time: p50={} p95={} max={}",
            fmt_duration(s.median()),
            fmt_duration(s.p95()),
            fmt_duration(s.max())
        );
    };
    summarize("SVEN (XLA)", &xla_seconds);
    summarize("SVEN (CPU)", &rust_seconds);
    println!("{}", service.metrics().report());
    service.shutdown();

    assert!(failed == 0, "all jobs must succeed");
    assert!(max_dev < 1e-3, "reduction must match glmnet (got {max_dev:.2e})");
    println!("\nEND-TO-END OK: artifacts + runtime + coordinator compose correctly");
    Ok(())
}
