//! Figure-1 style demo: trace the full regularization path on the
//! prostate-like data set with both glmnet and SVEN and print the β(t)
//! table — the textual version of the paper's Figure 1.
//!
//! Run: `cargo run --release --example regularization_path`
//! (uses the XLA backend too when `make artifacts` has been run)

use sven::coordinator::{path::max_deviation, PathRunner, PathRunnerConfig};
use sven::data::prostate_like;
use sven::solvers::sven::{RustBackend, Sven};

fn main() -> anyhow::Result<()> {
    let data = prostate_like(0);
    println!(
        "prostate-like data: n={} p={} (real set: 97 clinical records, 8 features)",
        data.n(),
        data.p()
    );

    let runner = PathRunner::new(PathRunnerConfig { grid: 20, ..Default::default() });
    let grid = runner.derive_grid(&data);
    println!("derived {} path settings from the glmnet path\n", grid.len());

    // SVEN (CPU)
    let sven_cpu = Sven::new(RustBackend::default());
    let results = runner.run(&data, &sven_cpu, &grid)?;

    println!("{:>9} {:>4}  {}", "t", "nnz", "beta (glmnet == sven, per feature)");
    for r in &results {
        let betas: Vec<String> = r.beta.iter().map(|b| format!("{b:+.3}")).collect();
        println!("{:>9.4} {:>4}  [{}]  dev={:.1e}", r.t, r.nnz, betas.join(" "), r.max_dev);
    }
    println!(
        "\nSVEN (CPU) max deviation from glmnet across the path: {:.2e}",
        max_deviation(&results)
    );

    // SVEN (XLA) if artifacts are available
    match sven::runtime::XlaBackend::from_default_dir() {
        Ok(backend) => {
            let sven_xla = Sven::new(backend);
            let results = runner.run(&data, &sven_xla, &grid)?;
            println!(
                "SVEN (XLA) max deviation from glmnet across the path: {:.2e}",
                max_deviation(&results)
            );
        }
        Err(e) => println!("SVEN (XLA) skipped ({e}) — run `make artifacts`"),
    }

    println!("\npaper's Figure 1 claim reproduced: the paths coincide for every t");
    Ok(())
}
