//! Domain example: p ≫ n feature selection on a gene-expression-style
//! data set (the paper's motivating workload — GLI-85 / SMK-CAN-187 are
//! transcriptional profiling sets with tens of thousands of probes and
//! fewer than 200 patients).
//!
//! The pipeline: generate a GLI-85-like design → derive the evaluation
//! grid → sweep it with SVEN → report support recovery (precision /
//! recall / F1 against the known ground truth) and timing per point.
//!
//! Run: `cargo run --release --example genomics_selection`

use sven::coordinator::{PathRunner, PathRunnerConfig};
use sven::data::{profile_by_name, Dataset};
use sven::solvers::sven::{RustBackend, Sven};
use sven::util::fmt_duration;

/// Support-recovery metrics against the generator's ground truth.
fn recovery(data: &Dataset, beta: &[f64]) -> (f64, f64, f64) {
    let truth = data.beta_true.as_ref().expect("synthetic set");
    let selected: Vec<bool> = beta.iter().map(|b| b.abs() > 1e-8).collect();
    let true_support: Vec<bool> = truth.iter().map(|b| b.abs() > 0.0).collect();
    let tp = selected
        .iter()
        .zip(&true_support)
        .filter(|(s, t)| **s && **t)
        .count() as f64;
    let fp = selected
        .iter()
        .zip(&true_support)
        .filter(|(s, t)| **s && !**t)
        .count() as f64;
    let fnn = selected
        .iter()
        .zip(&true_support)
        .filter(|(s, t)| !**s && **t)
        .count() as f64;
    let precision = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
    let recall = if tp + fnn > 0.0 { tp / (tp + fnn) } else { 0.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    (precision, recall, f1)
}

fn main() -> anyhow::Result<()> {
    // GLI-85 profile scaled as configured in data/profiles.rs: 85 glioma
    // samples, thousands of expression features, 40 informative.
    let profile = profile_by_name("GLI-85").expect("profile");
    println!(
        "dataset: {} — {} (paper shape {}x{}, ours {}x{})",
        profile.name, profile.about, profile.paper_n, profile.paper_p, profile.n, profile.p
    );
    let data = profile.generate(0);

    let runner = PathRunner::new(PathRunnerConfig { grid: 12, ..Default::default() });
    let grid = runner.derive_grid(&data);
    println!("evaluation grid: {} settings (paper protocol)\n", grid.len());

    let sven = Sven::new(RustBackend::default());
    let results = runner.run(&data, &sven, &grid)?;

    println!(
        "{:>9} {:>5} {:>8} {:>8} {:>8} {:>10} {:>10}",
        "t", "nnz", "prec", "recall", "F1", "time", "dev_glmnet"
    );
    let mut best = (0.0f64, 0usize);
    for (i, r) in results.iter().enumerate() {
        let (prec, rec, f1) = recovery(&data, &r.beta);
        if f1 > best.0 {
            best = (f1, i);
        }
        println!(
            "{:>9.3} {:>5} {:>8.3} {:>8.3} {:>8.3} {:>10} {:>10.1e}",
            r.t,
            r.nnz,
            prec,
            rec,
            f1,
            fmt_duration(r.seconds),
            r.max_dev
        );
    }
    let bi = best.1;
    println!(
        "\nbest F1 {:.3} at t={:.3} with {} features selected (true support: {})",
        best.0,
        results[bi].t,
        results[bi].nnz,
        data.beta_true.as_ref().unwrap().iter().filter(|b| b.abs() > 0.0).count()
    );
    println!("total sweep time: {}", fmt_duration(results.iter().map(|r| r.seconds).sum()));
    Ok(())
}
