//! Quickstart: solve one Elastic Net problem with SVEN and verify it
//! against the glmnet-style coordinate-descent reference.
//!
//! Run: `cargo run --release --example quickstart`

use sven::data::{synth_regression, SynthSpec};
use sven::linalg::vecops;
use sven::solvers::elastic_net::{penalized_to_constrained, EnProblem};
use sven::solvers::glmnet::{self, GlmnetConfig};
use sven::solvers::sven::{RustBackend, Sven};

fn main() -> anyhow::Result<()> {
    // 1. A small regression data set: 100 samples, 300 features, 10 of
    //    which carry signal (standardized by the generator).
    let data = synth_regression(&SynthSpec {
        name: "quickstart".into(),
        n: 100,
        p: 300,
        support: 10,
        rho: 0.4,
        snr: 4.0,
        ..Default::default()
    });
    println!("data: n={} p={}", data.n(), data.p());

    // 2. Reference solution from the CD baseline (penalized form), and
    //    the paper's protocol to convert it to a constrained (t, λ₂).
    let kappa = 0.5;
    let lambda = glmnet::cd::lambda_max(&data.x, &data.y, kappa) * 0.2;
    let reference = glmnet::solve_penalized(
        &data.x,
        &data.y,
        lambda,
        &GlmnetConfig { kappa, ..Default::default() },
        None,
    );
    let (t, lambda2) = penalized_to_constrained(&reference.beta, lambda, kappa, data.n());
    println!("grid point: t={t:.4} lambda2={lambda2:.4}");

    // 3. SVEN: reduce to a squared-hinge SVM and solve (rust backend; use
    //    `XlaBackend::from_default_dir()?` after `make artifacts` for the
    //    AOT/PJRT path).
    let sven = Sven::new(RustBackend::default());
    let problem = EnProblem::new(data.x.clone(), data.y.clone(), t, lambda2);
    let solution = sven.solve(&problem)?;

    // 4. The reduction is exact: coefficients match the CD reference.
    let max_dev = solution
        .beta
        .iter()
        .zip(&reference.beta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "sven: nnz={} |beta|_1={:.4} objective={:.6} solve_time={}",
        solution.nnz(),
        vecops::norm1(&solution.beta),
        solution.objective,
        sven::util::fmt_duration(solution.seconds)
    );
    println!("max |beta_sven − beta_glmnet| = {max_dev:.2e}");
    assert!(max_dev < 1e-4, "reduction must reproduce the CD solution");
    println!("OK — SVEN reproduces the Elastic Net solution via an SVM solve");
    Ok(())
}
